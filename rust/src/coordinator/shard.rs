//! Sharded front door: M coordinator shards behind a stateless router.
//!
//! The single-coordinator front door serializes every request on one
//! fleet-state mutex; past ~10K RPS the *lock*, not the replicas, is
//! the bottleneck (the ROADMAP's top open item).  This module splits
//! the fleet into M shards — each owning a partition of the replicas
//! with its **own** [`Fleet`] (and therefore its own
//! [`FleetGate`](crate::coordinator::admission::FleetGate), batcher,
//! and autoscaler view) — behind a thin router that consistent-hashes
//! each request's `(tenant, model)` key onto a virtual-node
//! [`HashRing`].  The router holds its `RwLock` only for the ring
//! lookup (reads, in the common case), so concurrent dispatches to
//! different shards proceed in parallel on the per-shard fleet locks.
//!
//! Elasticity: [`ShardedFleet::join`] brings up a new shard (ring
//! redistribution moves only the keys the joiner captures — ≈`1/M_new`
//! of them, the minimum; collateral movement between existing shards
//! is zero, far under the <5% budget — see [`super::ring`]).
//! [`ShardedFleet::leave`] retires a shard from the ring but **keeps
//! its fleet draining**, so riders already queued there still reach a
//! terminal outcome and the fleet-wide conservation law
//!
//! ```text
//! router arrivals == Σ_shards (completed + shed + lost + expired)
//! ```
//!
//! holds *through* a mid-trace re-partition, not just at rest
//! ([`ShardedReport::conserved`]).
//!
//! Telemetry: the router owns its own [`MetricsRegistry`] with a
//! fleet-wide `router_arrivals_total` and per-shard
//! `router_routed_total{shard="s<i>"}` counters, and its own sampled
//! [`Tracer`] emitting a `shard_route` span per sampled request.
//! Shard fleets keep their full per-fleet metrics/trace surface;
//! [`ShardedFleet::metrics_snapshot`] composes both.
//!
//! Virtual-time note: like the rest of `coordinator/`, this file may
//! touch the wall clock (it lives on the socket path); the fleet math
//! itself stays in virtual time — callers supply `Arrival::at_ms`.

use std::sync::{Arc, RwLock};

use crate::fleet::{Arrival, Fleet, FleetConfig, FleetReport, Placement, ScaleEvent};
use crate::runtime::artifacts::ModelId;
use crate::telemetry::metrics::{labeled, Counter, MetricsRegistry};
use crate::telemetry::trace::Tracer;
use crate::util::json::Json;
use crate::util::sync::{read_unpoisoned, write_unpoisoned};

use super::ring::{HashRing, DEFAULT_VNODES};

/// A placement plus the shard that made it — the handle
/// [`ShardedFleet::retract`] and autoscale-event pickup need to reach
/// the right shard again.
#[derive(Debug, Clone)]
pub struct Routed {
    pub shard: usize,
    pub placement: Placement,
}

struct Shard {
    fleet: Arc<Fleet>,
    /// Router-side routed counter (`router_routed_total{shard=...}`).
    routed: Arc<Counter>,
    /// False once the shard left the ring; the fleet stays alive to
    /// drain its queue, and its counters stay in the conservation sum.
    active: bool,
}

struct Topology {
    ring: HashRing,
    shards: Vec<Shard>,
}

/// See the module docs.
pub struct ShardedFleet {
    topo: RwLock<Topology>,
    metrics: Arc<MetricsRegistry>,
    arrivals: Arc<Counter>,
    tracer: Tracer,
    /// Full (unpartitioned) config; [`ShardedFleet::join`] provisions
    /// new shards from its replica list.
    template: FleetConfig,
    /// Shard count at construction — the modulus of the round-robin
    /// replica partition.
    initial_shards: usize,
}

impl ShardedFleet {
    /// Partition `cfg.replicas` round-robin across `shards` fleets
    /// (shard `i` takes replicas `i, i+M, i+2M, ...`).  Each shard
    /// clones the rest of the config — policy, budget, batching,
    /// autoscaling, artifact tier — so it is a complete fleet of its
    /// own; seeds are offset per shard to decorrelate tie-breaking.
    /// `shards` is clamped to at least 1.
    pub fn new(cfg: FleetConfig, shards: usize) -> ShardedFleet {
        let m = shards.max(1);
        let fleets = (0..m).map(|i| {
            let mut part = cfg.clone();
            part.replicas = cfg
                .replicas
                .iter()
                .enumerate()
                .filter(|(k, _)| k % m == i)
                .map(|(_, r)| r.clone())
                .collect();
            part.seed = cfg.seed.wrapping_add(i as u64);
            Arc::new(Fleet::new(part))
        });
        ShardedFleet::assemble(cfg, fleets.collect(), m)
    }

    /// Wrap one existing fleet as a single-shard front door (the
    /// `--fleet-shards 1` / legacy server path: routing is the
    /// identity, behavior matches the unsharded coordinator).
    pub fn single(fleet: Arc<Fleet>) -> ShardedFleet {
        let cfg = fleet.config().clone();
        ShardedFleet::assemble(cfg, vec![fleet], 1)
    }

    fn assemble(template: FleetConfig, fleets: Vec<Arc<Fleet>>, m: usize) -> ShardedFleet {
        let metrics = Arc::new(MetricsRegistry::new());
        let arrivals = metrics.counter("router_arrivals_total");
        let tracer = Tracer::new(4096, template.trace_every);
        let shards = fleets
            .into_iter()
            .enumerate()
            .map(|(i, fleet)| Shard {
                fleet,
                routed: metrics
                    .counter(&labeled("router_routed_total", &[("shard", &format!("s{i}"))])),
                active: true,
            })
            .collect();
        ShardedFleet {
            topo: RwLock::new(Topology { ring: HashRing::new(m, DEFAULT_VNODES), shards }),
            metrics,
            arrivals,
            tracer,
            template,
            initial_shards: m,
        }
    }

    /// Shards currently on the ring.
    pub fn active_shards(&self) -> usize {
        read_unpoisoned(&self.topo).shards.iter().filter(|s| s.active).count()
    }

    /// All shards ever created (retired ones included — they still
    /// drain and report).
    pub fn total_shards(&self) -> usize {
        read_unpoisoned(&self.topo).shards.len()
    }

    /// The shard the ring routes this key to right now.
    pub fn route(&self, tenant: Option<&str>, model: ModelId) -> Option<usize> {
        read_unpoisoned(&self.topo).ring.shard_for(tenant, model)
    }

    /// Shard `i`'s fleet (retired shards included).
    pub fn shard_fleet(&self, shard: usize) -> Option<Arc<Fleet>> {
        read_unpoisoned(&self.topo).shards.get(shard).map(|s| Arc::clone(&s.fleet))
    }

    /// Route by `(tenant, model)` and dispatch on the owning shard's
    /// fleet.  Returns `None` when that shard sheds the request (its
    /// gate, its capacity — exactly [`Fleet::dispatch`] semantics,
    /// counted on that shard so conservation sums fleet-wide).
    pub fn dispatch(&self, arrival: impl Into<Arrival>) -> Option<Routed> {
        let arrival = arrival.into();
        self.arrivals.inc();
        let trace = self.tracer.sample();
        let routed = {
            let topo = read_unpoisoned(&self.topo);
            topo.ring
                .shard_for(arrival.tenant.as_deref(), arrival.model)
                .and_then(|idx| topo.shards.get(idx).map(|s| (idx, s)))
                .map(|(idx, s)| {
                    s.routed.inc();
                    (idx, Arc::clone(&s.fleet))
                })
        };
        // The ring is never empty (constructors make ≥1 shard and
        // `leave` refuses the last), so `routed` is always `Some`;
        // the guard keeps the router total even if that ever changes.
        let (shard, fleet) = routed?;
        if let Some(id) = trace {
            self.tracer.event(
                id,
                "shard_route",
                format!(
                    "(tenant={}, model={}) -> s{shard}",
                    arrival.tenant.as_deref().unwrap_or("-"),
                    arrival.model.index()
                ),
                arrival.at_ms,
                0.0,
                shard as u32,
            );
        }
        fleet.dispatch(arrival).map(|placement| Routed { shard, placement })
    }

    /// Undo a routed placement whose real work failed (see
    /// [`Fleet::retract`]).
    pub fn retract(&self, routed: &Routed) -> bool {
        match self.shard_fleet(routed.shard) {
            Some(f) => f.retract(&routed.placement),
            None => false,
        }
    }

    /// Autoscale events that fired on `shard` since last asked.
    pub fn take_autoscale_events(&self, shard: usize) -> Vec<ScaleEvent> {
        self.shard_fleet(shard).map(|f| f.take_autoscale_events()).unwrap_or_default()
    }

    /// Bring up one new shard, provisioned with the template replica
    /// mix of partition `id % initial_shards`, and place it on the
    /// ring.  Returns the new shard's id.  Keys move only *to* the
    /// joiner (see the module docs).
    pub fn join(&self) -> usize {
        let mut topo = write_unpoisoned(&self.topo);
        let id = topo.shards.len();
        let m = self.initial_shards;
        let mut part = self.template.clone();
        part.replicas = self
            .template
            .replicas
            .iter()
            .enumerate()
            .filter(|(k, _)| k % m == id % m)
            .map(|(_, r)| r.clone())
            .collect();
        part.seed = self.template.seed.wrapping_add(id as u64);
        let routed = self
            .metrics
            .counter(&labeled("router_routed_total", &[("shard", &format!("s{id}"))]));
        topo.shards.push(Shard { fleet: Arc::new(Fleet::new(part)), routed, active: true });
        topo.ring.add_shard(id);
        id
    }

    /// Retire `shard` from the ring.  Its fleet keeps draining (and
    /// reporting) so no queued rider is dropped from the conservation
    /// sum.  Refuses (`false`) for an unknown or already-retired
    /// shard, and for the last active one — the ring must stay
    /// non-empty so every arrival keeps a route.
    pub fn leave(&self, shard: usize) -> bool {
        let mut topo = write_unpoisoned(&self.topo);
        let active = topo.shards.iter().filter(|s| s.active).count();
        let Some(s) = topo.shards.get_mut(shard) else {
            return false;
        };
        if !s.active || active <= 1 {
            return false;
        }
        s.active = false;
        topo.ring.remove_shard(shard);
        true
    }

    /// Advance every shard's virtual clock to `t_ms` (retired shards
    /// too — they are still draining).
    pub fn run_to(&self, t_ms: f64) {
        for f in self.fleets() {
            f.run_to(t_ms);
        }
    }

    /// Non-destructive snapshot across all shards.
    pub fn stats(&self) -> ShardedReport {
        self.report(Fleet::stats)
    }

    /// Run every shard's queue dry and aggregate the final reports.
    pub fn finish(&self) -> ShardedReport {
        self.report(Fleet::finish)
    }

    fn fleets(&self) -> Vec<Arc<Fleet>> {
        read_unpoisoned(&self.topo).shards.iter().map(|s| Arc::clone(&s.fleet)).collect()
    }

    fn report(&self, snap: impl Fn(&Fleet) -> FleetReport) -> ShardedReport {
        let shards: Vec<FleetReport> = self.fleets().iter().map(|f| snap(f.as_ref())).collect();
        let retired = {
            let topo = read_unpoisoned(&self.topo);
            topo.shards.iter().filter(|s| !s.active).count()
        };
        ShardedReport { arrivals: self.arrivals.get(), retired, shards }
    }

    /// The router's own registry (`router_arrivals_total`,
    /// `router_routed_total{shard=...}`); shard fleets keep theirs.
    pub fn metrics(&self) -> Arc<MetricsRegistry> {
        Arc::clone(&self.metrics)
    }

    /// Router snapshot plus every shard fleet's snapshot.  A
    /// single-shard front door stays wire-identical to the unsharded
    /// server (the shard fleet's snapshot alone); router counters are
    /// still reachable via [`ShardedFleet::metrics`].
    pub fn metrics_snapshot(&self) -> Json {
        let fleets = self.fleets();
        match (fleets.first(), fleets.len()) {
            (Some(f), 1) => f.metrics_snapshot(),
            _ => Json::object(vec![
                ("router", self.metrics.snapshot()),
                ("shards", Json::Array(fleets.iter().map(|f| f.metrics_snapshot()).collect())),
            ]),
        }
    }

    /// Fleet-stats wire payload: a single shard reports wire-identical
    /// to the unsharded [`FleetReport`](crate::fleet::FleetReport);
    /// M > 1 reports the sharded aggregate ([`ShardedReport::to_json`]).
    pub fn stats_json(&self) -> Json {
        let fleets = self.fleets();
        match (fleets.first(), fleets.len()) {
            (Some(f), 1) => f.stats().to_json(),
            _ => self.stats().to_json(),
        }
    }

    /// Chrome-trace export.  A single-shard front door stays
    /// wire-identical to the unsharded server (the shard fleet's
    /// spans); with M > 1 the router's `shard_route` spans are the
    /// story and are exported instead (per-shard spans remain
    /// reachable via [`ShardedFleet::shard_fleet`]).
    pub fn trace_chrome_json(&self) -> Json {
        let fleets = self.fleets();
        match (fleets.first(), fleets.len()) {
            (Some(f), 1) => f.trace_chrome_json(),
            _ => self.tracer.export_chrome(),
        }
    }

    /// Autoscaler snapshot: `None` when no shard has an autoscaler; a
    /// single shard reports wire-identically to the unsharded server,
    /// M > 1 reports `{"shards": [report-or-null, ...]}`.
    pub fn autoscale_json(&self) -> Option<Json> {
        let fleets = self.fleets();
        let reports: Vec<Option<Json>> =
            fleets.iter().map(|f| f.autoscale_report().map(|r| r.to_json())).collect();
        if reports.iter().all(Option::is_none) {
            return None;
        }
        if reports.len() == 1 {
            return reports.into_iter().next().flatten();
        }
        Some(Json::object(vec![(
            "shards",
            Json::Array(reports.into_iter().map(|r| r.unwrap_or(Json::Null)).collect()),
        )]))
    }

    /// Resolve a catalog model name (every shard shares the template
    /// catalog, so shard 0 answers for all).
    pub fn resolve_model(&self, name: &str) -> Option<ModelId> {
        self.fleets().first().and_then(|f| f.resolve_model(name))
    }

    pub fn has_catalog(&self) -> bool {
        self.fleets().first().is_some_and(|f| f.has_catalog())
    }
}

/// Fleet-wide aggregate over every shard (retired ones included).
#[derive(Debug, Clone)]
pub struct ShardedReport {
    /// Requests the *router* observed ([`ShardedFleet::dispatch`]
    /// calls) — the left side of the conservation law.
    pub arrivals: u64,
    /// Shards that have left the ring but are still counted.
    pub retired: usize,
    /// Per-shard reports, by shard id.
    pub shards: Vec<FleetReport>,
}

impl ShardedReport {
    fn sum(&self, f: impl Fn(&FleetReport) -> u64) -> u64 {
        self.shards.iter().map(f).sum()
    }

    pub fn completed(&self) -> u64 {
        self.sum(|r| r.completed)
    }

    pub fn shed(&self) -> u64 {
        self.sum(|r| r.shed)
    }

    pub fn lost(&self) -> u64 {
        self.sum(|r| r.lost)
    }

    pub fn expired(&self) -> u64 {
        self.sum(|r| r.expired)
    }

    /// `service + idle + artifact` joules across all shards.
    pub fn total_energy_j(&self) -> f64 {
        self.shards.iter().map(|r| r.total_energy_j).sum()
    }

    /// Upper bound on the fleet-wide p99: the worst per-shard p99.
    /// (Percentiles do not merge exactly; the max is conservative, so
    /// "sharded p99 ≤ single p99" claims are, if anything, understated.)
    pub fn p99_upper_ms(&self) -> Option<f64> {
        self.shards.iter().filter_map(|r| r.p99_ms).fold(None, |acc, x| {
            Some(match acc {
                Some(a) if a >= x => a,
                _ => x,
            })
        })
    }

    /// The conservation law, summed across shards — `true` iff every
    /// router arrival reached exactly one terminal outcome
    /// (`completed`, `shed`, `lost`, or `expired`) on exactly one
    /// shard.  Holds during and after join/leave re-partitioning
    /// because retired shards keep draining into this sum.
    pub fn conserved(&self) -> bool {
        self.arrivals == self.completed() + self.shed() + self.lost() + self.expired()
    }

    pub fn to_json(&self) -> Json {
        Json::object(vec![
            ("arrivals", Json::num(self.arrivals as f64)),
            ("completed", Json::num(self.completed() as f64)),
            ("shed", Json::num(self.shed() as f64)),
            ("lost", Json::num(self.lost() as f64)),
            ("expired", Json::num(self.expired() as f64)),
            ("conserved", Json::Bool(self.conserved())),
            ("retired_shards", Json::num(self.retired as f64)),
            ("total_energy_j", Json::num(self.total_energy_j())),
            (
                "p99_upper_ms",
                self.p99_upper_ms().map(Json::num).unwrap_or(Json::Null),
            ),
            ("shards", Json::Array(self.shards.iter().map(FleetReport::to_json).collect())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::Policy;

    fn cfg(spec: &str) -> FleetConfig {
        FleetConfig::parse_spec(spec, Policy::LeastLoaded).unwrap()
    }

    #[test]
    fn partitions_replicas_round_robin() {
        let sf = ShardedFleet::new(cfg("4xs7,2x6p"), 4);
        assert_eq!(sf.active_shards(), 4);
        let sizes: Vec<usize> =
            (0..4).map(|i| sf.shard_fleet(i).unwrap().len()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 6, "every replica lands somewhere");
        assert_eq!(sizes, vec![2, 2, 1, 1]);
    }

    #[test]
    fn single_shard_routes_everything_to_the_wrapped_fleet() {
        let fleet = Arc::new(Fleet::new(cfg("1xs7")));
        let sf = ShardedFleet::single(Arc::clone(&fleet));
        for i in 0..5 {
            assert_eq!(sf.dispatch(i as f64).map(|r| r.shard), Some(0));
        }
        let report = sf.finish();
        assert_eq!(report.arrivals, 5);
        assert!(report.conserved(), "{report:?}");
        assert_eq!(fleet.stats().completed, 5);
    }

    #[test]
    fn tenants_spread_across_shards_and_conservation_sums() {
        let sf = ShardedFleet::new(cfg("4xs7"), 4);
        let mut seen = std::collections::BTreeSet::new();
        for i in 0..200u64 {
            let a = Arrival::at(i as f64).with_tenant(format!("t{}", i % 31));
            let shard = sf.route(a.tenant.as_deref(), a.model).unwrap();
            let routed = sf.dispatch(a);
            if let Some(r) = &routed {
                assert_eq!(r.shard, shard, "dispatch must follow the ring");
            }
            seen.insert(shard);
        }
        assert!(seen.len() >= 3, "31 tenants should spread across shards: {seen:?}");
        let report = sf.finish();
        assert_eq!(report.arrivals, 200);
        assert!(report.conserved(), "{report:?}");
        // router metrics mirror the split
        let routed_sum = sf.metrics().counter_sum("router_routed_total");
        assert_eq!(routed_sum, 200);
        assert_eq!(sf.metrics().counter_value("router_arrivals_total"), Some(200));
    }

    #[test]
    fn leave_refuses_the_last_active_shard() {
        let sf = ShardedFleet::new(cfg("2xs7"), 2);
        assert!(sf.leave(0));
        assert!(!sf.leave(0), "already retired");
        assert!(!sf.leave(1), "last active shard must stay");
        assert!(!sf.leave(9), "unknown shard");
        assert_eq!(sf.active_shards(), 1);
        assert_eq!(sf.total_shards(), 2);
    }

    #[test]
    fn conservation_holds_through_a_mid_trace_repartition() {
        let sf = ShardedFleet::new(cfg("4xs7"), 2);
        let mut t = 0.0;
        let mut sent = 0u64;
        let mut send = |sf: &ShardedFleet, n: usize, t: &mut f64| {
            for k in 0..n {
                *t += 2.0;
                sf.dispatch(
                    Arrival::at(*t).with_tenant(format!("tenant-{}", k % 17)),
                );
            }
        };
        send(&sf, 50, &mut t);
        sent += 50;
        let id = sf.join();
        assert_eq!(id, 2);
        send(&sf, 50, &mut t);
        sent += 50;
        assert!(sf.leave(0), "retire a founding shard mid-trace");
        send(&sf, 50, &mut t);
        sent += 50;
        let report = sf.finish();
        assert_eq!(report.arrivals, sent);
        assert!(report.conserved(), "{report:?}");
        assert_eq!(report.retired, 1);
        // the retired shard finished what it had queued
        assert!(report.shards.first().is_some_and(|r| r.completed > 0));
        // nothing routes to shard 0 after it left
        assert_ne!(sf.route(Some("anyone"), ModelId::DEFAULT), Some(0));
    }

    #[test]
    fn shard_route_spans_are_sampled() {
        let mut c = cfg("2xs7");
        c.trace_every = 1;
        let sf = ShardedFleet::new(c, 2);
        for i in 0..4 {
            sf.dispatch(i as f64);
        }
        let trace = sf.trace_chrome_json();
        let events = trace.get("traceEvents").and_then(Json::as_array).unwrap();
        assert!(!events.is_empty(), "shard_route spans must be exported");
    }
}
