//! JSON-lines TCP front end and matching client.
//!
//! Wire protocol v2 (one JSON object per line; see
//! `rust/docs/WIRE_PROTOCOL.md` for the full contract):
//!
//! request  `{"v": 2, "cmd": "<name>", "args": {...}}` where `<name>`
//!          is one of `infer`, `stats`, `fleet_stats`,
//!          `autoscale_stats`, `metrics`, `trace_dump`, `quit`
//! response `{"ok": true, ...payload}` on success, or
//!          `{"ok": false, "error": {"code": "<stable_snake_case>",
//!          "msg": "..."}}` on failure
//!
//! The v1 forms — bare infer objects (`{"image_seed": 7, ...}` /
//! `{"image": [...]}`) and `{"cmd": "stats"}`-style commands — still
//! parse through the same command table; their replies keep the
//! legacy shape (`{"error": "..."}` on failure) plus a `"deprecated"`
//! note pointing at the v2 envelope.
//!
//! The server is a sharded front door: one nonblocking IO loop owns
//! every connection (no thread per socket), inference runs on
//! per-shard worker threads fed by bounded queues (a full queue sheds
//! with `shard_overloaded` instead of buffering without bound), and
//! `"fleet": true` requests route through the consistent-hash ring to
//! the shard that owns the `(tenant, model)` key (see
//! [`ShardedFleet`]).
//!
//! With `"fleet": true` the request is first routed through the
//! configured device fleet (see [`crate::fleet`]): the energy-aware
//! (or other) policy places it on a simulated Adreno replica, whose
//! predicted queue wait / latency / joules — and, when per-replica
//! batching is on (`--fleet-batch`), the size of the batch the
//! request rides in (`"batch_fill"`) — ride back on the response
//! while the real PJRT runtime computes the answer.  `"priority"`
//! (0 = bulk, default 1, higher = more urgent) and `"deadline_ms"`
//! (latency budget from arrival, wall clock) set the request's QoS
//! class on the fleet path.  When the fleet autoscaler is on
//! (`--fleet-autoscale`), scaling events that fired since the last
//! fleet-backed reply on that shard ride back too
//! (`"autoscale_events"`).  `"model"` (with `"fleet": true`) names a
//! catalog model when the fleet serves an artifact tier
//! (`--fleet-cache`); `"tenant"` (with `"fleet": true`) sets the
//! routing key's tenant half.
//!
//! Seed-addressed images keep the wire small for load generation:
//! both ends derive the pixels from the shared deterministic corpus.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender, TrySendError};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::fleet::{Arrival, Fleet};
use crate::model::ImageCorpus;
use crate::runtime::artifacts::ModelId;
use crate::simulator::device::Precision;
use crate::util::json::Json;

use super::engine::Coordinator;
use super::request::{InferResponse, Qos};
use super::shard::ShardedFleet;

/// Upper bound on one request line.  The largest legitimate request is
/// an inline `"image"` array (150528 floats, ~2.5 MB as text); 8 MiB
/// clears that with room while still bounding what one connection can
/// make the server buffer.
const MAX_REQUEST_BYTES: usize = 8 << 20;

/// Write-buffer cap per connection: a client that stops reading past
/// this much buffered reply data is dropped (slow-client protection —
/// the IO loop must never buffer one peer's replies without bound).
const MAX_WRITE_BUFFER_BYTES: usize = 8 << 20;

/// Depth of each shard worker's bounded job queue.  A full queue sheds
/// the request with `shard_overloaded` instead of blocking the IO
/// loop — backpressure is a visible error, never a stall.
const SHARD_QUEUE_DEPTH: usize = 256;

/// Deprecation note attached to every v1-shaped success reply.
const V1_DEPRECATION: &str = "v1 wire format is deprecated: send \
     {\"v\":2,\"cmd\":...,\"args\":{...}} (see rust/docs/WIRE_PROTOCOL.md)";

/// A wire error with a stable machine-readable code (the
/// `error.code` of a v2 reply).  Codes are part of the protocol
/// contract; see `rust/docs/WIRE_PROTOCOL.md` for the taxonomy.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WireError {
    pub code: &'static str,
    pub msg: String,
}

impl WireError {
    fn new(code: &'static str, msg: impl Into<String>) -> WireError {
        WireError { code, msg: msg.into() }
    }
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "[{}] {}", self.code, self.msg)
    }
}

impl std::error::Error for WireError {}

fn bad_args(msg: impl Into<String>) -> WireError {
    WireError::new("bad_args", msg)
}

fn no_fleet() -> WireError {
    WireError::new("no_fleet", "no fleet configured (start the server with --fleet SPEC)")
}

fn too_long() -> WireError {
    WireError::new("request_too_long", "request line too long")
}

/// A request line parsed into an inference (image, precision,
/// sim/fleet flags, QoS class, routing key) or a command.
#[derive(Debug)]
enum Parsed {
    Infer {
        image: Vec<f32>,
        precision: Precision,
        with_sim: bool,
        with_fleet: bool,
        qos: Qos,
        /// Catalog model name (fleet path only).
        model: Option<String>,
        /// Routing-key tenant (fleet path only).
        tenant: Option<String>,
    },
    Stats,
    FleetStats,
    AutoscaleStats,
    /// Fleet metrics-registry snapshot (`metrics`).
    Metrics,
    /// Sampled request-trace export as Chrome trace-event JSON
    /// (`trace_dump`).
    TraceDump,
    Quit,
}

/// A parsed request plus the wire dialect it arrived in, so the reply
/// can match the client's version.
#[derive(Debug)]
struct ParsedRequest {
    v: u8,
    parsed: Parsed,
}

type ArgParser = fn(&Json, usize) -> Result<Parsed, WireError>;

/// The full command taxonomy — one table drives dispatch for both
/// wire dialects (v1 command forms route through the same entries,
/// and a bare v1 infer object routes to `infer` with itself as args).
const COMMANDS: &[(&str, ArgParser)] = &[
    ("infer", parse_infer),
    ("stats", parse_stats),
    ("fleet_stats", parse_fleet_stats),
    ("autoscale_stats", parse_autoscale_stats),
    ("metrics", parse_metrics),
    ("trace_dump", parse_trace_dump),
    ("quit", parse_quit),
];

fn parse_stats(_: &Json, _: usize) -> Result<Parsed, WireError> {
    Ok(Parsed::Stats)
}

fn parse_fleet_stats(_: &Json, _: usize) -> Result<Parsed, WireError> {
    Ok(Parsed::FleetStats)
}

fn parse_autoscale_stats(_: &Json, _: usize) -> Result<Parsed, WireError> {
    Ok(Parsed::AutoscaleStats)
}

fn parse_metrics(_: &Json, _: usize) -> Result<Parsed, WireError> {
    Ok(Parsed::Metrics)
}

fn parse_trace_dump(_: &Json, _: usize) -> Result<Parsed, WireError> {
    Ok(Parsed::TraceDump)
}

fn parse_quit(_: &Json, _: usize) -> Result<Parsed, WireError> {
    Ok(Parsed::Quit)
}

fn parse_infer(args: &Json, image_len: usize) -> Result<Parsed, WireError> {
    let precision = match args.get("precision").and_then(Json::as_str).unwrap_or("precise") {
        "precise" => Precision::Precise,
        "imprecise" => Precision::Imprecise,
        "int8" | "i8" => Precision::Int8,
        other => return Err(bad_args(format!("unknown precision '{other}'"))),
    };
    let with_sim = args.get("sim").and_then(Json::as_bool).unwrap_or(false);
    let with_fleet = args.get("fleet").and_then(Json::as_bool).unwrap_or(false);
    let priority = match args.get("priority") {
        None => Qos::DEFAULT_PRIORITY,
        Some(p) => {
            let n = p.as_usize().ok_or_else(|| bad_args("priority must be an integer"))?;
            if n > u8::MAX as usize {
                return Err(bad_args("priority must be 0..=255"));
            }
            n as u8
        }
    };
    let deadline_ms = match args.get("deadline_ms") {
        None => None,
        Some(d) => Some(d.as_f64().ok_or_else(|| bad_args("deadline_ms must be a number"))?),
    };
    let qos = Qos { priority, deadline_ms };
    qos.validate().map_err(bad_args)?;
    let model = match args.get("model") {
        None => None,
        Some(m) => Some(m.as_str().ok_or_else(|| bad_args("model must be a string"))?.to_string()),
    };
    if model.is_some() && !with_fleet {
        return Err(bad_args(
            "\"model\" requires \"fleet\": true (models are served by the fleet's artifact tier)",
        ));
    }
    let tenant = match args.get("tenant") {
        None => None,
        Some(t) => {
            Some(t.as_str().ok_or_else(|| bad_args("tenant must be a string"))?.to_string())
        }
    };
    if tenant.is_some() && !with_fleet {
        return Err(bad_args(
            "\"tenant\" requires \"fleet\": true (tenancy is a fleet routing key)",
        ));
    }
    let image = if let Some(raw) = args.get("image").and_then(Json::as_array) {
        let img: Vec<f32> = raw.iter().filter_map(|x| x.as_f64()).map(|x| x as f32).collect();
        if img.len() != image_len {
            return Err(bad_args(format!("image must have {image_len} values")));
        }
        img
    } else {
        let seed = args.get("image_seed").and_then(Json::as_usize).unwrap_or(0) as u64;
        let index = args.get("image_index").and_then(Json::as_usize).unwrap_or(0) as u64;
        ImageCorpus::new(seed).image(index)
    };
    Ok(Parsed::Infer { image, precision, with_sim, with_fleet, qos, model, tenant })
}

/// Parse one request line in either wire dialect.  Errors carry the
/// dialect the request arrived in so the error reply can match it.
fn parse_request(line: &str, image_len: usize) -> Result<ParsedRequest, (u8, WireError)> {
    let v = Json::parse(line)
        .map_err(|e| (1, WireError::new("bad_json", format!("request is not valid JSON: {e}"))))?;
    let version = match v.get("v") {
        None => 1,
        Some(n) => match n.as_usize() {
            Some(1) => 1,
            Some(2) => 2,
            _ => return Err((2, WireError::new("bad_version", "\"v\" must be 1 or 2"))),
        },
    };
    let (cmd, args) = if version >= 2 {
        let Some(cmd) = v.get("cmd").and_then(Json::as_str) else {
            return Err((2, bad_args("a v2 envelope requires a \"cmd\" string")));
        };
        let args = match v.get("args") {
            None => Json::object(vec![]),
            Some(a @ Json::Object(_)) => a.clone(),
            Some(_) => return Err((2, bad_args("\"args\" must be an object"))),
        };
        (cmd, args)
    } else if let Some(cmd) = v.get("cmd").and_then(Json::as_str) {
        (cmd, Json::object(vec![]))
    } else {
        // bare v1 infer object: the whole request is the args
        ("infer", v.clone())
    };
    let Some((_, parse)) = COMMANDS.iter().find(|(name, _)| *name == cmd) else {
        return Err((version, WireError::new("unknown_cmd", format!("unknown cmd '{cmd}'"))));
    };
    let parsed = parse(&args, image_len).map_err(|e| (version, e))?;
    Ok(ParsedRequest { v: version, parsed })
}

/// Wrap a payload in the versioned success envelope: v2 replies lead
/// with `"ok": true`; v1 replies keep the legacy shape plus a
/// deprecation note.
fn reply_ok(v: u8, payload: Json) -> Json {
    let mut pairs = match payload {
        Json::Object(pairs) => pairs,
        other => vec![("result".to_string(), other)],
    };
    if v >= 2 {
        pairs.insert(0, ("ok".to_string(), Json::Bool(true)));
    } else {
        pairs.push(("deprecated".to_string(), Json::str(V1_DEPRECATION)));
    }
    Json::Object(pairs)
}

/// The versioned error envelope: v2 gets `{"ok": false, "error":
/// {"code", "msg"}}`; v1 keeps the legacy `{"error": "..."}` string
/// (plus the stable code and the deprecation note as new keys).
fn reply_err(v: u8, e: &WireError) -> Json {
    if v >= 2 {
        Json::object(vec![
            ("ok", Json::Bool(false)),
            (
                "error",
                Json::object(vec![("code", Json::str(e.code)), ("msg", Json::str(e.msg.clone()))]),
            ),
        ])
    } else {
        Json::object(vec![
            ("error", Json::str(e.msg.clone())),
            ("error_code", Json::str(e.code)),
            ("deprecated", Json::str(V1_DEPRECATION)),
        ])
    }
}

/// One inference in flight between the IO loop and a shard worker.
struct InferJob {
    conn: u64,
    v: u8,
    image: Vec<f32>,
    precision: Precision,
    with_sim: bool,
    qos: Qos,
    /// `Some` = fleet path with the resolved catalog model.
    model: Option<ModelId>,
    tenant: Option<String>,
    arrival_ms: f64,
}

/// One client connection owned by the IO loop: nonblocking socket plus
/// read/write buffers and the count of replies still owed by workers.
struct Conn {
    stream: TcpStream,
    rbuf: Vec<u8>,
    wbuf: Vec<u8>,
    inflight: usize,
    read_closed: bool,
    dead: bool,
}

impl Conn {
    fn new(stream: TcpStream) -> Conn {
        Conn {
            stream,
            rbuf: Vec::new(),
            wbuf: Vec::new(),
            inflight: 0,
            read_closed: false,
            dead: false,
        }
    }

    /// Drain readable bytes into `rbuf`; returns true on progress.
    fn pump_reads(&mut self) -> bool {
        let mut progressed = false;
        let mut chunk = [0u8; 16384];
        while !self.read_closed && !self.dead {
            match self.stream.read(&mut chunk) {
                Ok(0) => self.read_closed = true,
                Ok(n) => {
                    if let Some(part) = chunk.get(..n) {
                        self.rbuf.extend_from_slice(part);
                    }
                    progressed = true;
                    if self.overflowed() {
                        break;
                    }
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(ref e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(_) => self.dead = true,
            }
        }
        progressed
    }

    /// A client streaming bytes without a newline would grow `rbuf`
    /// without bound; past the cap the caller replies with
    /// `request_too_long` and hangs up.
    fn overflowed(&self) -> bool {
        self.rbuf.len() > MAX_REQUEST_BYTES && !self.rbuf.contains(&b'\n')
    }

    fn next_line(&mut self) -> Option<String> {
        let pos = self.rbuf.iter().position(|&b| b == b'\n')?;
        let mut raw: Vec<u8> = self.rbuf.drain(..=pos).collect();
        raw.pop();
        if raw.last() == Some(&b'\r') {
            raw.pop();
        }
        Some(String::from_utf8_lossy(&raw).into_owned())
    }

    fn push_reply(&mut self, reply: &Json) {
        self.push_reply_line(&reply.to_string());
    }

    fn push_reply_line(&mut self, line: &str) {
        self.wbuf.extend_from_slice(line.as_bytes());
        self.wbuf.push(b'\n');
        if self.wbuf.len() > MAX_WRITE_BUFFER_BYTES {
            self.dead = true;
        }
    }

    /// Flush what the socket will take; returns true on progress.
    fn pump_writes(&mut self) -> bool {
        let mut progressed = false;
        while !self.dead && !self.wbuf.is_empty() {
            match self.stream.write(&self.wbuf) {
                Ok(0) => self.dead = true,
                Ok(n) => {
                    self.wbuf.drain(..n);
                    progressed = true;
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(ref e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(_) => self.dead = true,
            }
        }
        progressed
    }

    /// A connection stays until it errors, or the peer closed its
    /// half and every owed reply has been flushed.
    fn alive(&self) -> bool {
        !self.dead && !(self.read_closed && self.wbuf.is_empty() && self.inflight == 0)
    }
}

/// Everything the IO loop needs to answer a parsed line.
struct ServerCtx {
    coordinator: Arc<Coordinator>,
    fleet: Option<Arc<ShardedFleet>>,
    started: Instant,
    stop: Arc<AtomicBool>,
    job_txs: Vec<SyncSender<InferJob>>,
}

impl ServerCtx {
    /// Catch the fleet's virtual clock up to wall time so snapshots
    /// reflect long-finished requests.
    fn catch_up(&self) -> Option<&Arc<ShardedFleet>> {
        let f = self.fleet.as_ref()?;
        f.run_to(self.started.elapsed().as_secs_f64() * 1e3);
        Some(f)
    }

    fn command_payload(&self, parsed: &Parsed) -> Result<Json, WireError> {
        match parsed {
            Parsed::Stats => {
                Ok(Json::object(vec![("stats", Json::str(self.coordinator.telemetry.report()))]))
            }
            Parsed::FleetStats => {
                let f = self.catch_up().ok_or_else(no_fleet)?;
                Ok(Json::object(vec![("fleet_stats", f.stats_json())]))
            }
            Parsed::Metrics => {
                let f = self.catch_up().ok_or_else(no_fleet)?;
                Ok(Json::object(vec![("metrics", f.metrics_snapshot())]))
            }
            Parsed::TraceDump => {
                let f = self.catch_up().ok_or_else(no_fleet)?;
                Ok(Json::object(vec![("trace", f.trace_chrome_json())]))
            }
            Parsed::AutoscaleStats => {
                let f = self.catch_up().ok_or_else(no_fleet)?;
                let report = f.autoscale_json().ok_or_else(|| {
                    WireError::new(
                        "no_autoscaler",
                        "no autoscaler configured (start the server with --fleet-autoscale KV)",
                    )
                })?;
                Ok(Json::object(vec![("autoscale_stats", report)]))
            }
            // infer and quit never reach here (routed in handle_line)
            Parsed::Infer { .. } | Parsed::Quit => Err(bad_args("not a command")),
        }
    }

    fn handle_line(&self, id: u64, line: &str, conn: &mut Conn) {
        let line = line.trim();
        if line.is_empty() {
            return;
        }
        if line.len() > MAX_REQUEST_BYTES {
            conn.push_reply(&reply_err(1, &too_long()));
            conn.read_closed = true;
            return;
        }
        let ParsedRequest { v, parsed } =
            match parse_request(line, self.coordinator.image_len()) {
                Ok(pr) => pr,
                Err((v, e)) => {
                    conn.push_reply(&reply_err(v, &e));
                    return;
                }
            };
        match parsed {
            Parsed::Quit => {
                self.stop.store(true, Ordering::Relaxed);
                let payload = if v >= 2 {
                    Json::object(vec![])
                } else {
                    Json::object(vec![("ok", Json::Bool(true))])
                };
                conn.push_reply(&reply_ok(v, payload));
            }
            Parsed::Infer { image, precision, with_sim, with_fleet, qos, model, tenant } => {
                self.submit_infer(
                    id,
                    v,
                    conn,
                    InferParams { image, precision, with_sim, with_fleet, qos, model, tenant },
                );
            }
            other => {
                let reply = match self.command_payload(&other) {
                    Ok(payload) => reply_ok(v, payload),
                    Err(e) => reply_err(v, &e),
                };
                conn.push_reply(&reply);
            }
        }
    }

    /// Resolve the fleet/model half of an infer on the IO thread (so
    /// routing errors answer immediately), then hand the work to the
    /// worker that owns the target shard.
    fn submit_infer(&self, id: u64, v: u8, conn: &mut Conn, p: InferParams) {
        let model = match (p.with_fleet, self.fleet.as_deref()) {
            (false, _) => None,
            (true, None) => {
                conn.push_reply(&reply_err(v, &no_fleet()));
                return;
            }
            (true, Some(sf)) => {
                let model_id = match &p.model {
                    None => ModelId::DEFAULT,
                    Some(name) => match sf.resolve_model(name) {
                        Some(m) => m,
                        None => {
                            let e = if sf.has_catalog() {
                                WireError::new(
                                    "unknown_model",
                                    format!("unknown model '{name}' (not in the artifact catalog)"),
                                )
                            } else {
                                WireError::new(
                                    "no_catalog",
                                    "no model catalog configured (start the server with \
                                     --fleet-cache MB)",
                                )
                            };
                            conn.push_reply(&reply_err(v, &e));
                            return;
                        }
                    },
                };
                Some(model_id)
            }
        };
        // The worker that owns the target shard gets the job, so one
        // shard's traffic queues behind its own work, not a neighbor's.
        let widx = match (model, self.fleet.as_deref()) {
            (Some(m), Some(sf)) => {
                sf.route(p.tenant.as_deref(), m).unwrap_or(0) % self.job_txs.len().max(1)
            }
            _ => 0,
        };
        let arrival_ms = self.started.elapsed().as_secs_f64() * 1e3;
        let job = InferJob {
            conn: id,
            v,
            image: p.image,
            precision: p.precision,
            with_sim: p.with_sim,
            qos: p.qos,
            model,
            tenant: p.tenant,
            arrival_ms,
        };
        let Some(tx) = self.job_txs.get(widx) else {
            conn.push_reply(&reply_err(v, &WireError::new("infer_failed", "no worker available")));
            return;
        };
        match tx.try_send(job) {
            Ok(()) => conn.inflight += 1,
            Err(TrySendError::Full(j)) => conn.push_reply(&reply_err(
                j.v,
                &WireError::new("shard_overloaded", "shard worker queue full: request shed"),
            )),
            Err(TrySendError::Disconnected(j)) => conn.push_reply(&reply_err(
                j.v,
                &WireError::new("infer_failed", "server shutting down"),
            )),
        }
    }
}

struct InferParams {
    image: Vec<f32>,
    precision: Precision,
    with_sim: bool,
    with_fleet: bool,
    qos: Qos,
    model: Option<String>,
    tenant: Option<String>,
}

fn worker_loop(
    rx: Receiver<InferJob>,
    coordinator: Arc<Coordinator>,
    fleet: Option<Arc<ShardedFleet>>,
    replies: Sender<(u64, String)>,
) {
    while let Ok(job) = rx.recv() {
        let conn = job.conn;
        let reply = run_infer(&coordinator, fleet.as_deref(), job);
        if replies.send((conn, reply.to_string())).is_err() {
            break;
        }
    }
}

/// Fleet admission runs *before* the real inference, so an overload
/// shed costs nothing; if the inference then fails, the placement is
/// retracted so the fleet never meters joules for an answer that was
/// not served.
fn run_infer(coordinator: &Coordinator, fleet: Option<&ShardedFleet>, job: InferJob) -> Json {
    let InferJob { conn: _, v, image, precision, with_sim, qos, model, tenant, arrival_ms } = job;
    let routed = match (model, fleet) {
        (Some(m), Some(sf)) => {
            let mut arrival = Arrival::at(arrival_ms).with_qos(qos).with_model(m);
            if let Some(t) = tenant {
                arrival = arrival.with_tenant(t);
            }
            match sf.dispatch(arrival) {
                Some(r) => Some(r),
                None => {
                    return reply_err(
                        v,
                        &WireError::new("fleet_overloaded", "fleet overloaded: request shed"),
                    )
                }
            }
        }
        _ => None,
    };
    match coordinator.infer_qos(image, precision, with_sim, qos) {
        Ok(resp) => {
            let mut reply = resp.to_json();
            if let (Some(r), Json::Object(pairs)) = (&routed, &mut reply) {
                let mut pj = r.placement.to_json();
                if let Json::Object(ppairs) = &mut pj {
                    ppairs.push(("shard".to_string(), Json::num(r.shard as f64)));
                    // Scaling events since the last fleet reply on this
                    // shard ride back on the placement, so load
                    // generators see scale-up/down as it happens.
                    if let Some(sf) = fleet {
                        let events = sf.take_autoscale_events(r.shard);
                        if !events.is_empty() {
                            ppairs.push((
                                "autoscale_events".to_string(),
                                Json::Array(events.iter().map(|e| e.to_json()).collect()),
                            ));
                        }
                    }
                }
                pairs.push(("fleet".to_string(), pj));
            }
            reply_ok(v, reply)
        }
        Err(e) => {
            if let (Some(r), Some(sf)) = (&routed, fleet) {
                sf.retract(r);
            }
            reply_err(v, &WireError::new("infer_failed", format!("{e:#}")))
        }
    }
}

/// Serve until `stop` is set or a client sends `quit`.  Returns the
/// bound address via the callback.  No fleet: the `"fleet": true`
/// path answers `no_fleet`.
pub fn serve(
    coordinator: Arc<Coordinator>,
    addr: &str,
    stop: Arc<AtomicBool>,
    on_bound: impl FnOnce(std::net::SocketAddr),
) -> Result<()> {
    serve_sharded(coordinator, None, addr, stop, on_bound)
}

/// [`serve`] with a single-fleet back end: the fleet is wrapped in a
/// one-shard [`ShardedFleet`], which keeps every wire payload
/// identical to the pre-shard server.
pub fn serve_with_fleet(
    coordinator: Arc<Coordinator>,
    fleet: Option<Arc<Fleet>>,
    addr: &str,
    stop: Arc<AtomicBool>,
    on_bound: impl FnOnce(std::net::SocketAddr),
) -> Result<()> {
    let sharded = fleet.map(|f| Arc::new(ShardedFleet::single(f)));
    serve_sharded(coordinator, sharded, addr, stop, on_bound)
}

/// The sharded front door: one nonblocking IO loop owns every
/// connection; inference runs on one worker thread per shard, fed by
/// bounded queues keyed off the fleet's consistent-hash ring.
/// Wall-clock arrival times (ms since server start) drive the fleet's
/// virtual clock.
pub fn serve_sharded(
    coordinator: Arc<Coordinator>,
    fleet: Option<Arc<ShardedFleet>>,
    addr: &str,
    stop: Arc<AtomicBool>,
    on_bound: impl FnOnce(std::net::SocketAddr),
) -> Result<()> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    listener.set_nonblocking(true)?;
    on_bound(listener.local_addr()?);

    let workers = fleet.as_ref().map_or(1, |f| f.active_shards().max(1));
    let (reply_tx, reply_rx) = std::sync::mpsc::channel::<(u64, String)>();
    let mut job_txs = Vec::with_capacity(workers);
    let mut worker_handles = Vec::with_capacity(workers);
    for _ in 0..workers {
        let (tx, rx) = sync_channel::<InferJob>(SHARD_QUEUE_DEPTH);
        job_txs.push(tx);
        let c = Arc::clone(&coordinator);
        let f = fleet.clone();
        let r = reply_tx.clone();
        worker_handles.push(std::thread::spawn(move || worker_loop(rx, c, f, r)));
    }
    drop(reply_tx);

    let ctx = ServerCtx {
        coordinator,
        fleet,
        started: Instant::now(),
        stop: Arc::clone(&stop),
        job_txs,
    };
    let mut conns: HashMap<u64, Conn> = HashMap::new();
    let mut next_id: u64 = 0;

    while !stop.load(Ordering::Relaxed) {
        let mut busy = false;
        loop {
            match listener.accept() {
                Ok((stream, _)) => {
                    stream.set_nonblocking(true).ok();
                    stream.set_nodelay(true).ok();
                    conns.insert(next_id, Conn::new(stream));
                    next_id += 1;
                    busy = true;
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(ref e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e).context("accept"),
            }
        }
        for (&id, conn) in conns.iter_mut() {
            busy |= conn.pump_reads();
            while let Some(line) = conn.next_line() {
                busy = true;
                ctx.handle_line(id, &line, conn);
            }
            if conn.overflowed() {
                conn.push_reply(&reply_err(1, &too_long()));
                conn.rbuf.clear();
                conn.read_closed = true;
            }
        }
        while let Ok((id, line)) = reply_rx.try_recv() {
            busy = true;
            if let Some(conn) = conns.get_mut(&id) {
                conn.inflight = conn.inflight.saturating_sub(1);
                conn.push_reply_line(&line);
            }
        }
        for conn in conns.values_mut() {
            busy |= conn.pump_writes();
        }
        conns.retain(|_, c| c.alive());
        if !busy {
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
    }

    // Shutdown: dropping the job queues ends the workers; flush any
    // replies they already computed so quitting clients get answers.
    drop(ctx);
    for h in worker_handles {
        let _ = h.join();
    }
    while let Ok((id, line)) = reply_rx.try_recv() {
        if let Some(conn) = conns.get_mut(&id) {
            conn.push_reply_line(&line);
        }
    }
    for conn in conns.values_mut() {
        conn.pump_writes();
    }
    Ok(())
}

/// One v2 request: the seven commands of the wire taxonomy.  Build
/// inference requests with [`InferBuilder`] and send any request with
/// [`Client::call`].
#[derive(Debug, Clone)]
pub enum Request {
    Infer(InferBuilder),
    Stats,
    FleetStats,
    AutoscaleStats,
    Metrics,
    TraceDump,
    Quit,
}

impl Request {
    fn cmd(&self) -> &'static str {
        match self {
            Request::Infer(_) => "infer",
            Request::Stats => "stats",
            Request::FleetStats => "fleet_stats",
            Request::AutoscaleStats => "autoscale_stats",
            Request::Metrics => "metrics",
            Request::TraceDump => "trace_dump",
            Request::Quit => "quit",
        }
    }

    fn args(&self) -> Json {
        match self {
            Request::Infer(b) => b.args_json(),
            _ => Json::object(vec![]),
        }
    }
}

/// Builder for the `infer` command's args.  Start from
/// [`InferBuilder::seed`] (corpus-addressed image — keeps the wire
/// small) or [`InferBuilder::image`] (inline pixels), then chain the
/// optional knobs; `.model()` and `.tenant()` imply the fleet path.
#[derive(Debug, Clone)]
pub struct InferBuilder {
    seed: u64,
    index: u64,
    image: Option<Vec<f32>>,
    precision: Precision,
    sim: bool,
    fleet: bool,
    qos: Qos,
    model: Option<String>,
    tenant: Option<String>,
}

impl Default for InferBuilder {
    fn default() -> InferBuilder {
        InferBuilder {
            seed: 0,
            index: 0,
            image: None,
            precision: Precision::Precise,
            sim: false,
            fleet: false,
            qos: Qos::default(),
            model: None,
            tenant: None,
        }
    }
}

impl InferBuilder {
    /// Corpus-addressed image: both ends derive the pixels from the
    /// shared deterministic corpus.
    pub fn seed(seed: u64, index: u64) -> InferBuilder {
        InferBuilder { seed, index, ..InferBuilder::default() }
    }

    /// Inline pixels (must match the model's input length).
    pub fn image(pixels: Vec<f32>) -> InferBuilder {
        InferBuilder { image: Some(pixels), ..InferBuilder::default() }
    }

    pub fn precision(mut self, precision: Precision) -> InferBuilder {
        self.precision = precision;
        self
    }

    pub fn sim(mut self, on: bool) -> InferBuilder {
        self.sim = on;
        self
    }

    pub fn fleet(mut self, on: bool) -> InferBuilder {
        self.fleet = on;
        self
    }

    pub fn priority(mut self, priority: u8) -> InferBuilder {
        self.qos.priority = priority;
        self
    }

    pub fn deadline_ms(mut self, deadline_ms: f64) -> InferBuilder {
        self.qos.deadline_ms = Some(deadline_ms);
        self
    }

    pub fn qos(mut self, qos: Qos) -> InferBuilder {
        self.qos = qos;
        self
    }

    /// Catalog model name; implies `"fleet": true`.
    pub fn model(mut self, name: &str) -> InferBuilder {
        self.model = Some(name.to_string());
        self.fleet = true;
        self
    }

    /// Routing-key tenant; implies `"fleet": true`.
    pub fn tenant(mut self, name: &str) -> InferBuilder {
        self.tenant = Some(name.to_string());
        self.fleet = true;
        self
    }

    fn args_json(&self) -> Json {
        let mut pairs = Vec::new();
        if let Some(img) = &self.image {
            pairs.push((
                "image",
                Json::Array(img.iter().map(|&x| Json::num(f64::from(x))).collect()),
            ));
        } else {
            pairs.push(("image_seed", Json::num(self.seed as f64)));
            pairs.push(("image_index", Json::num(self.index as f64)));
        }
        pairs.push(("precision", Json::str(self.precision.label())));
        if self.sim {
            pairs.push(("sim", Json::Bool(true)));
        }
        if self.fleet {
            pairs.push(("fleet", Json::Bool(true)));
        }
        pairs.push(("priority", Json::num(f64::from(self.qos.priority))));
        if let Some(d) = self.qos.deadline_ms {
            pairs.push(("deadline_ms", Json::num(d)));
        }
        if let Some(m) = &self.model {
            pairs.push(("model", Json::str(m.clone())));
        }
        if let Some(t) = &self.tenant {
            pairs.push(("tenant", Json::str(t.clone())));
        }
        Json::object(pairs)
    }
}

/// Minimal blocking client for the JSON-lines protocol.  Every
/// request goes through [`Client::call`] as a v2 envelope; the legacy
/// per-command methods are thin wrappers kept for ergonomics.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

/// A parsed inference reply.
#[derive(Debug, Clone)]
pub struct ClientReply {
    pub top1: usize,
    pub latency_ms: f64,
    pub batch_size: usize,
    pub raw: Json,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        stream.set_nodelay(true).ok();
        Ok(Client { reader: BufReader::new(stream.try_clone()?), writer: stream })
    }

    /// Send one request as a v2 envelope and return the reply payload.
    /// Server failures surface as errors carrying the stable wire code
    /// (`server error [code]: msg`); v1-shaped replies from an older
    /// server are accepted too.
    pub fn call(&mut self, req: &Request) -> Result<Json> {
        let envelope = Json::object(vec![
            ("v", Json::num(2.0)),
            ("cmd", Json::str(req.cmd())),
            ("args", req.args()),
        ]);
        writeln!(self.writer, "{envelope}")?;
        let mut line = String::new();
        self.reader.read_line(&mut line).context("reading reply")?;
        let v = Json::parse(line.trim()).context("parsing reply")?;
        match v.get("ok").and_then(Json::as_bool) {
            Some(true) => Ok(v),
            Some(false) => {
                let code = v
                    .get("error")
                    .and_then(|e| e.get("code"))
                    .and_then(Json::as_str)
                    .unwrap_or("unknown")
                    .to_string();
                let msg = v
                    .get("error")
                    .and_then(|e| e.get("msg"))
                    .and_then(Json::as_str)
                    .unwrap_or("")
                    .to_string();
                anyhow::bail!("server error [{code}]: {msg}")
            }
            None => match v.get("error").and_then(Json::as_str) {
                Some(err) => anyhow::bail!("server error: {err}"),
                None => Ok(v),
            },
        }
    }

    /// Run one inference described by the builder.
    pub fn infer(&mut self, req: InferBuilder) -> Result<ClientReply> {
        let v = self.call(&Request::Infer(req))?;
        Ok(ClientReply {
            top1: v.get("top1").and_then(Json::as_usize).context("reply missing top1")?,
            latency_ms: v.get("latency_ms").and_then(Json::as_f64).unwrap_or(0.0),
            batch_size: v.get("batch_size").and_then(Json::as_usize).unwrap_or(1),
            raw: v,
        })
    }

    /// Infer on a corpus-addressed image.
    pub fn infer_seed(
        &mut self,
        seed: u64,
        index: u64,
        precision: Precision,
        with_sim: bool,
    ) -> Result<ClientReply> {
        self.infer(InferBuilder::seed(seed, index).precision(precision).sim(with_sim))
    }

    /// [`infer_seed`](Self::infer_seed) with an explicit QoS class
    /// (`"priority"` / `"deadline_ms"` on the wire).
    pub fn infer_seed_qos(
        &mut self,
        seed: u64,
        index: u64,
        precision: Precision,
        with_sim: bool,
        qos: Qos,
    ) -> Result<ClientReply> {
        self.infer(InferBuilder::seed(seed, index).precision(precision).sim(with_sim).qos(qos))
    }

    /// Fleet-backed inference for a named catalog model: sets
    /// `"fleet": true` and `"model"` on the wire.  The reply's
    /// `"fleet"` placement object carries the model name and any
    /// `"cold_load_ms"` the request triggered.
    pub fn infer_seed_model(
        &mut self,
        seed: u64,
        index: u64,
        precision: Precision,
        model: &str,
        qos: Qos,
    ) -> Result<ClientReply> {
        self.infer(InferBuilder::seed(seed, index).precision(precision).model(model).qos(qos))
    }

    /// Fetch the server's telemetry report.
    pub fn stats(&mut self) -> Result<String> {
        let v = self.call(&Request::Stats)?;
        Ok(v.get("stats").and_then(Json::as_str).unwrap_or("").to_string())
    }

    /// Fetch the fleet report (errors when the server has no fleet).
    pub fn fleet_stats(&mut self) -> Result<Json> {
        let v = self.call(&Request::FleetStats)?;
        v.get("fleet_stats").cloned().context("reply missing fleet_stats")
    }

    /// Fetch the autoscaler report (errors when the server has no
    /// fleet or no autoscaler).
    pub fn autoscale_stats(&mut self) -> Result<Json> {
        let v = self.call(&Request::AutoscaleStats)?;
        v.get("autoscale_stats").cloned().context("reply missing autoscale_stats")
    }

    /// Fetch the fleet's metrics-registry snapshot (errors when the
    /// server has no fleet).
    pub fn metrics(&mut self) -> Result<Json> {
        let v = self.call(&Request::Metrics)?;
        v.get("metrics").cloned().context("reply missing metrics")
    }

    /// Fetch the sampled request traces as Chrome trace-event JSON
    /// (errors when the server has no fleet; empty `traceEvents` when
    /// sampling is off).
    pub fn trace_dump(&mut self) -> Result<Json> {
        let v = self.call(&Request::TraceDump)?;
        v.get("trace").cloned().context("reply missing trace")
    }

    /// Ask the server to stop.
    pub fn quit(&mut self) -> Result<()> {
        let _ = self.call(&Request::Quit)?;
        Ok(())
    }
}

/// `InferResponse` parsing helper shared with tests.
pub fn response_top1(resp: &InferResponse) -> usize {
    resp.top1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_seed_request() {
        let pr = parse_request(r#"{"image_seed": 3, "precision": "imprecise"}"#, 12).unwrap();
        assert_eq!(pr.v, 1);
        match pr.parsed {
            Parsed::Infer { image, precision, with_sim, with_fleet, qos, model, tenant } => {
                assert_eq!(image.len(), crate::model::images::IMAGE_LEN);
                assert_eq!(precision, Precision::Imprecise);
                assert!(!with_sim);
                assert!(!with_fleet);
                assert_eq!(qos, Qos::default());
                assert_eq!(model, None);
                assert_eq!(tenant, None);
            }
            _ => panic!("expected infer"),
        }
    }

    #[test]
    fn parses_model_field() {
        let pr = parse_request(r#"{"image_seed": 1, "fleet": true, "model": "detector"}"#, 12)
            .unwrap();
        match pr.parsed {
            Parsed::Infer { model, with_fleet, .. } => {
                assert_eq!(model.as_deref(), Some("detector"));
                assert!(with_fleet);
            }
            _ => panic!("expected infer"),
        }
        // a model without the fleet path is a visible error, as is a
        // non-string model
        assert!(parse_request(r#"{"image_seed": 1, "model": "detector"}"#, 12).is_err());
        assert!(parse_request(r#"{"image_seed": 1, "fleet": true, "model": 3}"#, 12).is_err());
    }

    #[test]
    fn parses_tenant_field() {
        let pr = parse_request(r#"{"image_seed": 1, "fleet": true, "tenant": "acme"}"#, 12)
            .unwrap();
        match pr.parsed {
            Parsed::Infer { tenant, .. } => assert_eq!(tenant.as_deref(), Some("acme")),
            _ => panic!("expected infer"),
        }
        // tenancy is a fleet routing key: without the fleet path it is
        // a visible error, as is a non-string tenant
        assert!(parse_request(r#"{"image_seed": 1, "tenant": "acme"}"#, 12).is_err());
        assert!(parse_request(r#"{"image_seed": 1, "fleet": true, "tenant": 7}"#, 12).is_err());
    }

    #[test]
    fn parses_fleet_request() {
        let pr = parse_request(r#"{"image_seed": 1, "fleet": true}"#, 12).unwrap();
        match pr.parsed {
            Parsed::Infer { with_fleet, .. } => assert!(with_fleet),
            _ => panic!("expected infer"),
        }
    }

    #[test]
    fn parses_qos_fields() {
        let pr = parse_request(
            r#"{"image_seed": 1, "fleet": true, "priority": 3, "deadline_ms": 450.5}"#,
            12,
        )
        .unwrap();
        match pr.parsed {
            Parsed::Infer { qos, .. } => {
                assert_eq!(qos.priority, 3);
                assert_eq!(qos.deadline_ms, Some(450.5));
                assert!(qos.is_interactive());
            }
            _ => panic!("expected infer"),
        }
        // bulk is priority 0, no deadline
        let pr = parse_request(r#"{"image_seed": 1, "priority": 0}"#, 12).unwrap();
        match pr.parsed {
            Parsed::Infer { qos, .. } => assert_eq!(qos, Qos::bulk()),
            _ => panic!("expected infer"),
        }
        // malformed QoS is an error, not a silent default
        assert!(parse_request(r#"{"image_seed": 1, "priority": 300}"#, 12).is_err());
        assert!(parse_request(r#"{"image_seed": 1, "priority": "high"}"#, 12).is_err());
        assert!(parse_request(r#"{"image_seed": 1, "deadline_ms": -5}"#, 12).is_err());
        assert!(parse_request(r#"{"image_seed": 1, "deadline_ms": "soon"}"#, 12).is_err());
    }

    #[test]
    fn parses_raw_image_request() {
        let pr = parse_request(r#"{"image": [0.1, 0.2, 0.3]}"#, 3).unwrap();
        match pr.parsed {
            Parsed::Infer { image, .. } => assert_eq!(image, vec![0.1, 0.2, 0.3]),
            _ => panic!("expected infer"),
        }
    }

    #[test]
    fn rejects_bad_requests() {
        assert!(parse_request("not json", 3).is_err());
        assert!(parse_request(r#"{"image": [1.0]}"#, 3).is_err());
        assert!(parse_request(r#"{"precision": "half"}"#, 3).is_err());
        assert!(parse_request(r#"{"cmd": "dance"}"#, 3).is_err());
    }

    #[test]
    fn v2_envelope_parses_via_the_command_table() {
        let pr = parse_request(
            r#"{"v": 2, "cmd": "infer", "args": {"image_seed": 3, "fleet": true, "tenant": "acme"}}"#,
            12,
        )
        .unwrap();
        assert_eq!(pr.v, 2);
        match pr.parsed {
            Parsed::Infer { with_fleet, tenant, .. } => {
                assert!(with_fleet);
                assert_eq!(tenant.as_deref(), Some("acme"));
            }
            _ => panic!("expected infer"),
        }
        let pr = parse_request(r#"{"v": 2, "cmd": "stats"}"#, 12).unwrap();
        assert_eq!(pr.v, 2);
        assert!(matches!(pr.parsed, Parsed::Stats));
        // every command name in the table is reachable through v2
        for (name, _) in COMMANDS {
            let line = format!("{{\"v\": 2, \"cmd\": \"{name}\"}}");
            assert!(parse_request(&line, 12).is_ok(), "cmd '{name}' must parse");
        }
        // non-object args are a visible error
        assert!(parse_request(r#"{"v": 2, "cmd": "stats", "args": 3}"#, 12).is_err());
    }

    #[test]
    fn v2_errors_carry_stable_codes() {
        let code = |line: &str| parse_request(line, 12).unwrap_err().1.code;
        assert_eq!(code("not json"), "bad_json");
        assert_eq!(code(r#"{"v": 3, "cmd": "stats"}"#), "bad_version");
        assert_eq!(code(r#"{"v": 2}"#), "bad_args");
        assert_eq!(code(r#"{"v": 2, "cmd": "dance"}"#), "unknown_cmd");
        assert_eq!(code(r#"{"v": 2, "cmd": "infer", "args": {"priority": 300}}"#), "bad_args");
        // the dialect of the failing request rides back so the error
        // reply can match the client's version
        assert_eq!(parse_request("not json", 12).unwrap_err().0, 1);
        assert_eq!(parse_request(r#"{"v": 2, "cmd": "dance"}"#, 12).unwrap_err().0, 2);
        assert_eq!(parse_request(r#"{"cmd": "dance"}"#, 12).unwrap_err().0, 1);
    }

    /// The wire-compat contract: every documented v1 request form
    /// still parses, in the v1 dialect, through the v2 command table.
    #[test]
    fn v1_wire_forms_still_round_trip() {
        let forms = [
            r#"{"image_seed": 7, "image_index": 0, "precision": "precise", "sim": true}"#,
            r#"{"image_seed": 1, "fleet": true, "priority": 2, "deadline_ms": 500}"#,
            r#"{"image_seed": 1, "fleet": true, "model": "squeezenet"}"#,
            r#"{"image": [0.1, 0.2, 0.3]}"#,
            r#"{"cmd": "stats"}"#,
            r#"{"cmd": "fleet_stats"}"#,
            r#"{"cmd": "autoscale_stats"}"#,
            r#"{"cmd": "metrics"}"#,
            r#"{"cmd": "trace_dump"}"#,
            r#"{"cmd": "quit"}"#,
        ];
        for form in forms {
            let pr = parse_request(form, 3)
                .unwrap_or_else(|e| panic!("v1 form {form} broke: {e:?}"));
            assert_eq!(pr.v, 1, "v1 form {form} must keep its dialect");
        }
        // an explicit "v": 1 also maps to the legacy dialect
        assert_eq!(parse_request(r#"{"v": 1, "cmd": "stats"}"#, 3).unwrap().v, 1);
    }

    #[test]
    fn fleet_stats_wire_rows_carry_the_replica_kind() {
        // A native (real-compute) replica is declared with the same
        // spec grammar the config/wire already speak ("native" atom);
        // its fleet_stats row must say what services it, so a client
        // can tell measured wall-clock rows from cost-model rows.
        let cfg = crate::fleet::FleetConfig::parse_spec(
            "native,1xn5",
            crate::fleet::Policy::RoundRobin,
        )
        .unwrap();
        let sharded = ShardedFleet::new(cfg, 1);
        let stats = sharded.stats_json();
        let rows = stats.get("replicas").and_then(Json::as_array).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].get("kind").and_then(Json::as_str), Some("native"));
        assert_eq!(rows[0].get("device").and_then(Json::as_str), Some("Host CPU"));
        assert_eq!(rows[1].get("kind").and_then(Json::as_str), Some("simulated"));
    }

    #[test]
    fn reply_envelopes_are_versioned() {
        let ok2 = reply_ok(2, Json::object(vec![("x", Json::num(1.0))]));
        assert_eq!(ok2.get("ok").and_then(Json::as_bool), Some(true));
        assert!(ok2.get("x").is_some());
        assert!(ok2.get("deprecated").is_none());

        let ok1 = reply_ok(1, Json::object(vec![("x", Json::num(1.0))]));
        assert!(ok1.get("ok").is_none(), "v1 replies keep the legacy shape");
        assert!(ok1.get("x").is_some());
        assert!(ok1.get("deprecated").and_then(Json::as_str).is_some());

        let err2 = reply_err(2, &WireError::new("bad_args", "nope"));
        assert_eq!(err2.get("ok").and_then(Json::as_bool), Some(false));
        assert_eq!(
            err2.get("error").and_then(|e| e.get("code")).and_then(Json::as_str),
            Some("bad_args")
        );
        assert_eq!(
            err2.get("error").and_then(|e| e.get("msg")).and_then(Json::as_str),
            Some("nope")
        );

        let err1 = reply_err(1, &WireError::new("bad_args", "nope"));
        assert_eq!(err1.get("error").and_then(Json::as_str), Some("nope"));
        assert_eq!(err1.get("error_code").and_then(Json::as_str), Some("bad_args"));
    }

    #[test]
    fn infer_builder_emits_the_documented_args() {
        let b = InferBuilder::seed(3, 1)
            .precision(Precision::Imprecise)
            .sim(true)
            .priority(2)
            .deadline_ms(450.0)
            .model("detector")
            .tenant("acme");
        let args = b.args_json();
        assert_eq!(args.get("image_seed").and_then(Json::as_usize), Some(3));
        assert_eq!(args.get("image_index").and_then(Json::as_usize), Some(1));
        assert_eq!(args.get("precision").and_then(Json::as_str), Some("imprecise"));
        // .model() implies the fleet path
        assert_eq!(args.get("fleet").and_then(Json::as_bool), Some(true));
        assert_eq!(args.get("model").and_then(Json::as_str), Some("detector"));
        assert_eq!(args.get("tenant").and_then(Json::as_str), Some("acme"));
        assert_eq!(args.get("priority").and_then(Json::as_usize), Some(2));
        assert_eq!(args.get("deadline_ms").and_then(Json::as_f64), Some(450.0));
        // and the emitted args re-parse as the same request
        let line = Json::object(vec![
            ("v", Json::num(2.0)),
            ("cmd", Json::str("infer")),
            ("args", args),
        ])
        .to_string();
        let pr = parse_request(&line, 12).unwrap();
        assert_eq!(pr.v, 2);
        match pr.parsed {
            Parsed::Infer { qos, model, tenant, with_fleet, .. } => {
                assert_eq!(qos, Qos { priority: 2, deadline_ms: Some(450.0) });
                assert_eq!(model.as_deref(), Some("detector"));
                assert_eq!(tenant.as_deref(), Some("acme"));
                assert!(with_fleet);
            }
            _ => panic!("expected infer"),
        }
    }

    /// Seeded corruption of valid requests: every mutant must come
    /// back `Ok` or `Err` — a panic here is a crashed server loop in
    /// production.  The LCG makes failures reproducible.
    #[test]
    fn seeded_bad_input_is_an_error_never_a_panic() {
        const ROUNDS: usize = 500;
        let seeds = [
            r#"{"image_seed": 3, "precision": "imprecise"}"#,
            r#"{"image_seed": 1, "fleet": true, "priority": 2, "deadline_ms": 500, "model": "m"}"#,
            r#"{"image": [0.1, 0.2, 0.3]}"#,
            r#"{"cmd": "metrics"}"#,
            r#"{"v": 2, "cmd": "infer", "args": {"image_seed": 1, "fleet": true, "tenant": "t"}}"#,
        ];
        let pool: Vec<char> = "{}[]\",:0123456789.eE+-truefalsnm ".chars().collect();
        let mut state: u64 = 0x00c0ffee;
        let mut rand = move |m: usize| -> usize {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((state >> 33) as usize) % m.max(1)
        };
        for _ in 0..ROUNDS {
            let base: Vec<char> = seeds[rand(seeds.len())].chars().collect();
            let mut mutant = base.clone();
            match rand(3) {
                0 => mutant.truncate(rand(base.len() + 1)),
                1 => {
                    let i = rand(base.len());
                    mutant[i] = pool[rand(pool.len())];
                }
                _ => {
                    let i = rand(base.len() + 1);
                    mutant.insert(i, pool[rand(pool.len())]);
                }
            }
            let text: String = mutant.into_iter().collect();
            let _ = parse_request(&text, 3); // Ok or Err both fine
        }
        // Known nasties are errors, not aborts: a bracket bomb burns
        // one parser stack frame per `[` without the depth guard.
        let bomb = format!("{{\"image\": {}", "[".repeat(100_000));
        assert!(parse_request(&bomb, 3).is_err(), "deep nesting is a visible error");
    }

    #[test]
    fn parses_commands() {
        let parsed = |line: &str| parse_request(line, 3).unwrap().parsed;
        assert!(matches!(parsed(r#"{"cmd": "stats"}"#), Parsed::Stats));
        assert!(matches!(parsed(r#"{"cmd": "fleet_stats"}"#), Parsed::FleetStats));
        assert!(matches!(parsed(r#"{"cmd": "autoscale_stats"}"#), Parsed::AutoscaleStats));
        assert!(matches!(parsed(r#"{"cmd": "metrics"}"#), Parsed::Metrics));
        assert!(matches!(parsed(r#"{"cmd": "trace_dump"}"#), Parsed::TraceDump));
        assert!(matches!(parsed(r#"{"cmd": "quit"}"#), Parsed::Quit));
    }
}
