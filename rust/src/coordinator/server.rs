//! JSON-lines TCP front end and matching client.
//!
//! Wire protocol (one JSON object per line):
//!
//! request  `{"image_seed": 7, "image_index": 0, "precision": "precise",
//!            "sim": true, "fleet": true, "priority": 2,
//!            "deadline_ms": 500, "model": "squeezenet"}`
//!          or `{"image": [ ...150528 floats... ], ...}`
//!          or `{"cmd": "stats"}` / `{"cmd": "fleet_stats"}` /
//!          `{"cmd": "autoscale_stats"}` / `{"cmd": "metrics"}` /
//!          `{"cmd": "trace_dump"}` / `{"cmd": "quit"}`
//! response the [`InferResponse::to_json`] object (plus a `"fleet"`
//!          placement object when the request set `"fleet": true`), or
//!          `{"error": "..."}` / `{"stats": "..."}` /
//!          `{"fleet_stats": {...}}` / `{"autoscale_stats": {...}}`.
//!
//! With `"fleet": true` the request is first routed through the
//! configured device fleet (see [`crate::fleet`]): the energy-aware (or
//! other) policy places it on a simulated Adreno replica, whose
//! predicted queue wait / latency / joules — and, when per-replica
//! batching is on (`--fleet-batch`), the size of the batch the request
//! rides in (`"batch_fill"`) — ride back on the response while the
//! real PJRT runtime computes the answer.  `"priority"` (0 = bulk,
//! default 1, higher = more urgent) and `"deadline_ms"` (latency
//! budget from arrival, wall clock) set the request's QoS class on
//! the fleet path: priority-aware shedding at the gate,
//! deadline-aware placement, early batch flush, and expiry at
//! dequeue.  When the fleet autoscaler
//! is on (`--fleet-autoscale`), scaling events that fired since the
//! last fleet-backed reply ride back too (`"autoscale_events"`), and
//! `{"cmd": "autoscale_stats"}` snapshots the whole control loop.
//! `"model"` (with `"fleet": true`) names a catalog model when the
//! fleet serves an artifact tier (`--fleet-cache`): placement becomes
//! affinity-aware, the reply's placement object reports the model and
//! any `"cold_load_ms"` the request triggered, and an unknown model
//! name is an error.
//!
//! Seed-addressed images keep the wire small for load generation: both
//! ends derive the pixels from the shared deterministic corpus.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::fleet::Fleet;
use crate::model::ImageCorpus;
use crate::runtime::artifacts::ModelId;
use crate::simulator::device::Precision;
use crate::util::json::Json;

use super::engine::Coordinator;
use super::request::{InferResponse, Qos};

/// Upper bound on one request line.  The largest legitimate request is
/// an inline `"image"` array (150528 floats, ~2.5 MB as text); 8 MiB
/// clears that with room while still bounding what one connection can
/// make the handler buffer.
const MAX_REQUEST_BYTES: usize = 8 << 20;

/// Parse a request line into an inference (image, precision, sim/fleet
/// flags, QoS class) or a command.
enum Parsed {
    Infer {
        image: Vec<f32>,
        precision: Precision,
        with_sim: bool,
        with_fleet: bool,
        qos: Qos,
        /// Catalog model name (fleet path only).
        model: Option<String>,
    },
    Stats,
    FleetStats,
    AutoscaleStats,
    /// Fleet metrics-registry snapshot (`{"cmd":"metrics"}`).
    Metrics,
    /// Sampled request-trace export as Chrome trace-event JSON
    /// (`{"cmd":"trace_dump"}`).
    TraceDump,
    Quit,
}

fn parse_request(line: &str, image_len: usize) -> Result<Parsed> {
    let v = Json::parse(line).context("request is not valid JSON")?;
    if let Some(cmd) = v.get("cmd").and_then(Json::as_str) {
        return match cmd {
            "stats" => Ok(Parsed::Stats),
            "fleet_stats" => Ok(Parsed::FleetStats),
            "autoscale_stats" => Ok(Parsed::AutoscaleStats),
            "metrics" => Ok(Parsed::Metrics),
            "trace_dump" => Ok(Parsed::TraceDump),
            "quit" => Ok(Parsed::Quit),
            other => anyhow::bail!("unknown cmd '{other}'"),
        };
    }
    let precision = match v.get("precision").and_then(Json::as_str).unwrap_or("precise") {
        "precise" => Precision::Precise,
        "imprecise" => Precision::Imprecise,
        other => anyhow::bail!("unknown precision '{other}'"),
    };
    let with_sim = v.get("sim").and_then(Json::as_bool).unwrap_or(false);
    let with_fleet = v.get("fleet").and_then(Json::as_bool).unwrap_or(false);
    let priority = match v.get("priority") {
        None => Qos::DEFAULT_PRIORITY,
        Some(p) => {
            let n = p.as_usize().context("priority must be an integer")?;
            anyhow::ensure!(n <= u8::MAX as usize, "priority must be 0..=255");
            n as u8
        }
    };
    let deadline_ms = match v.get("deadline_ms") {
        None => None,
        Some(d) => Some(d.as_f64().context("deadline_ms must be a number")?),
    };
    let qos = Qos { priority, deadline_ms };
    qos.validate().map_err(|e| anyhow::anyhow!(e))?;
    let model = match v.get("model") {
        None => None,
        Some(m) => Some(m.as_str().context("model must be a string")?.to_string()),
    };
    anyhow::ensure!(
        model.is_none() || with_fleet,
        "\"model\" requires \"fleet\": true (models are served by the fleet's artifact tier)"
    );
    let image = if let Some(raw) = v.get("image").and_then(Json::as_array) {
        let img: Vec<f32> = raw.iter().filter_map(|x| x.as_f64()).map(|x| x as f32).collect();
        anyhow::ensure!(img.len() == image_len, "image must have {image_len} values");
        img
    } else {
        let seed = v.get("image_seed").and_then(Json::as_usize).unwrap_or(0) as u64;
        let index = v.get("image_index").and_then(Json::as_usize).unwrap_or(0) as u64;
        ImageCorpus::new(seed).image(index)
    };
    Ok(Parsed::Infer { image, precision, with_sim, with_fleet, qos, model })
}

/// Serve until `stop` is set (checked between connections) or a client
/// sends `{"cmd":"quit"}`. Returns the bound address via the callback.
pub fn serve(
    coordinator: Arc<Coordinator>,
    addr: &str,
    stop: Arc<AtomicBool>,
    on_bound: impl FnOnce(std::net::SocketAddr),
) -> Result<()> {
    serve_with_fleet(coordinator, None, addr, stop, on_bound)
}

/// [`serve`] with an optional device fleet backing the `"fleet": true`
/// infer path and the `fleet_stats` command.  Wall-clock arrival times
/// (ms since server start) drive the fleet's virtual clock.
pub fn serve_with_fleet(
    coordinator: Arc<Coordinator>,
    fleet: Option<Arc<Fleet>>,
    addr: &str,
    stop: Arc<AtomicBool>,
    on_bound: impl FnOnce(std::net::SocketAddr),
) -> Result<()> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    listener.set_nonblocking(true)?;
    on_bound(listener.local_addr()?);
    let started = Instant::now();
    let mut handles = Vec::new();
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _)) => {
                let c = coordinator.clone();
                let f = fleet.clone();
                let s = stop.clone();
                handles.push(std::thread::spawn(move || {
                    let _ = handle_client(c, f, started, stream, s);
                }));
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            Err(e) => return Err(e).context("accept"),
        }
    }
    for h in handles {
        let _ = h.join();
    }
    Ok(())
}

fn handle_client(
    coordinator: Arc<Coordinator>,
    fleet: Option<Arc<Fleet>>,
    started: Instant,
    stream: TcpStream,
    stop: Arc<AtomicBool>,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    // Read with a timeout so idle handler threads notice `stop` and
    // exit — otherwise server shutdown would block on open connections.
    stream.set_read_timeout(Some(std::time::Duration::from_millis(100)))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        // Accumulate into `line` across timeouts until a full line is in.
        match reader.read_line(&mut line) {
            Ok(0) => break, // EOF
            Ok(_) if !line.ends_with('\n') => {
                // A client streaming bytes without a newline would grow
                // `line` without bound; cap the request and hang up.
                if line.len() > MAX_REQUEST_BYTES {
                    writeln!(
                        writer,
                        "{}",
                        Json::object(vec![("error", Json::str("request line too long"))])
                    )?;
                    break;
                }
                continue;
            }
            Ok(_) if line.len() > MAX_REQUEST_BYTES => {
                writeln!(
                    writer,
                    "{}",
                    Json::object(vec![("error", Json::str("request line too long"))])
                )?;
                break;
            }
            Ok(_) => {}
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if stop.load(Ordering::Relaxed) {
                    break;
                }
                continue;
            }
            Err(e) => return Err(e.into()),
        }
        let request = std::mem::take(&mut line);
        let request = request.trim();
        if request.is_empty() {
            continue;
        }
        let reply = match parse_request(request, coordinator.image_len()) {
            Ok(Parsed::Quit) => {
                stop.store(true, Ordering::Relaxed);
                writeln!(writer, "{}", Json::object(vec![("ok", Json::Bool(true))]))?;
                break;
            }
            Ok(Parsed::Stats) => {
                Json::object(vec![("stats", Json::str(coordinator.telemetry.report()))])
            }
            Ok(Parsed::FleetStats) => match &fleet {
                Some(f) => {
                    // Catch the virtual clock up to wall time so the
                    // snapshot reflects long-finished requests.
                    f.run_to(started.elapsed().as_secs_f64() * 1e3);
                    Json::object(vec![("fleet_stats", f.stats().to_json())])
                }
                None => Json::object(vec![(
                    "error",
                    Json::str("no fleet configured (start the server with --fleet SPEC)"),
                )]),
            },
            Ok(Parsed::Metrics) => match &fleet {
                Some(f) => {
                    f.run_to(started.elapsed().as_secs_f64() * 1e3);
                    Json::object(vec![("metrics", f.metrics_snapshot())])
                }
                None => Json::object(vec![(
                    "error",
                    Json::str("no fleet configured (start the server with --fleet SPEC)"),
                )]),
            },
            Ok(Parsed::TraceDump) => match &fleet {
                Some(f) => {
                    f.run_to(started.elapsed().as_secs_f64() * 1e3);
                    Json::object(vec![("trace", f.trace_chrome_json())])
                }
                None => Json::object(vec![(
                    "error",
                    Json::str("no fleet configured (start the server with --fleet SPEC)"),
                )]),
            },
            Ok(Parsed::AutoscaleStats) => match &fleet {
                Some(f) => {
                    f.run_to(started.elapsed().as_secs_f64() * 1e3);
                    match f.autoscale_report() {
                        Some(report) => {
                            Json::object(vec![("autoscale_stats", report.to_json())])
                        }
                        None => Json::object(vec![(
                            "error",
                            Json::str(
                                "no autoscaler configured (start the server with \
                                 --fleet-autoscale KV)",
                            ),
                        )]),
                    }
                }
                None => Json::object(vec![(
                    "error",
                    Json::str("no fleet configured (start the server with --fleet SPEC)"),
                )]),
            },
            Ok(Parsed::Infer { image, precision, with_sim, with_fleet, qos, model }) => {
                // Fleet admission runs *before* the real inference, so
                // an overload shed costs nothing; if the inference then
                // fails, the placement is retracted so the fleet never
                // meters joules for an answer that was not served.
                let placement = match (with_fleet, &fleet) {
                    (false, _) => Ok(None),
                    (true, None) => {
                        Err("no fleet configured (start the server with --fleet SPEC)".to_string())
                    }
                    (true, Some(f)) => {
                        let model_id = match &model {
                            None => Ok(ModelId::DEFAULT),
                            Some(name) => f.resolve_model(name).ok_or_else(|| {
                                if f.has_catalog() {
                                    format!("unknown model '{name}' (not in the artifact catalog)")
                                } else {
                                    "no model catalog configured (start the server with \
                                     --fleet-cache MB)"
                                        .to_string()
                                }
                            }),
                        };
                        model_id.and_then(|m| {
                            let arrival_ms = started.elapsed().as_secs_f64() * 1e3;
                            f.dispatch_model(arrival_ms, qos, m)
                                .map(Some)
                                .ok_or_else(|| "fleet overloaded: request shed".to_string())
                        })
                    }
                };
                match placement {
                    Err(e) => Json::object(vec![("error", Json::str(e))]),
                    Ok(placement) => match coordinator.infer_qos(image, precision, with_sim, qos)
                    {
                        Ok(resp) => {
                            let mut reply = resp.to_json();
                            if let (Some(p), Json::Object(pairs)) = (placement, &mut reply) {
                                let mut pj = p.to_json();
                                // Scaling events since the last fleet
                                // reply ride back on the placement, so
                                // load generators see scale-up/down as
                                // it happens.
                                if let Some(f) = &fleet {
                                    let events = f.take_autoscale_events();
                                    if !events.is_empty() {
                                        if let Json::Object(ppairs) = &mut pj {
                                            ppairs.push((
                                                "autoscale_events".to_string(),
                                                Json::Array(
                                                    events
                                                        .iter()
                                                        .map(|e| e.to_json())
                                                        .collect(),
                                                ),
                                            ));
                                        }
                                    }
                                }
                                pairs.push(("fleet".to_string(), pj));
                            }
                            reply
                        }
                        Err(e) => {
                            if let (Some(p), Some(f)) = (&placement, &fleet) {
                                f.retract(p);
                            }
                            Json::object(vec![("error", Json::str(format!("{e:#}")))])
                        }
                    },
                }
            }
            Err(e) => Json::object(vec![("error", Json::str(format!("{e:#}")))]),
        };
        writeln!(writer, "{reply}")?;
    }
    Ok(())
}

/// Minimal blocking client for the JSON-lines protocol.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

/// A parsed inference reply.
#[derive(Debug, Clone)]
pub struct ClientReply {
    pub top1: usize,
    pub latency_ms: f64,
    pub batch_size: usize,
    pub raw: Json,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        stream.set_nodelay(true).ok();
        Ok(Client { reader: BufReader::new(stream.try_clone()?), writer: stream })
    }

    fn round_trip(&mut self, req: Json) -> Result<Json> {
        writeln!(self.writer, "{req}")?;
        let mut line = String::new();
        self.reader.read_line(&mut line).context("reading reply")?;
        let v = Json::parse(line.trim()).context("parsing reply")?;
        if let Some(err) = v.get("error").and_then(Json::as_str) {
            anyhow::bail!("server error: {err}");
        }
        Ok(v)
    }

    /// Infer on a corpus-addressed image.
    pub fn infer_seed(
        &mut self,
        seed: u64,
        index: u64,
        precision: Precision,
        with_sim: bool,
    ) -> Result<ClientReply> {
        self.infer_seed_qos(seed, index, precision, with_sim, Qos::default())
    }

    /// [`infer_seed`](Self::infer_seed) with an explicit QoS class
    /// (`"priority"` / `"deadline_ms"` on the wire).
    pub fn infer_seed_qos(
        &mut self,
        seed: u64,
        index: u64,
        precision: Precision,
        with_sim: bool,
        qos: Qos,
    ) -> Result<ClientReply> {
        let mut pairs = vec![
            ("image_seed", Json::num(seed as f64)),
            ("image_index", Json::num(index as f64)),
            ("precision", Json::str(precision.label())),
            ("sim", Json::Bool(with_sim)),
            ("priority", Json::num(f64::from(qos.priority))),
        ];
        if let Some(d) = qos.deadline_ms {
            pairs.push(("deadline_ms", Json::num(d)));
        }
        let v = self.round_trip(Json::object(pairs))?;
        Ok(ClientReply {
            top1: v.get("top1").and_then(Json::as_usize).context("reply missing top1")?,
            latency_ms: v.get("latency_ms").and_then(Json::as_f64).unwrap_or(0.0),
            batch_size: v.get("batch_size").and_then(Json::as_usize).unwrap_or(1),
            raw: v,
        })
    }

    /// Fleet-backed inference for a named catalog model: sets
    /// `"fleet": true` and `"model"` on the wire.  The reply's
    /// `"fleet"` placement object carries the model name and any
    /// `"cold_load_ms"` the request triggered.
    pub fn infer_seed_model(
        &mut self,
        seed: u64,
        index: u64,
        precision: Precision,
        model: &str,
        qos: Qos,
    ) -> Result<ClientReply> {
        let mut pairs = vec![
            ("image_seed", Json::num(seed as f64)),
            ("image_index", Json::num(index as f64)),
            ("precision", Json::str(precision.label())),
            ("fleet", Json::Bool(true)),
            ("model", Json::str(model)),
            ("priority", Json::num(f64::from(qos.priority))),
        ];
        if let Some(d) = qos.deadline_ms {
            pairs.push(("deadline_ms", Json::num(d)));
        }
        let v = self.round_trip(Json::object(pairs))?;
        Ok(ClientReply {
            top1: v.get("top1").and_then(Json::as_usize).context("reply missing top1")?,
            latency_ms: v.get("latency_ms").and_then(Json::as_f64).unwrap_or(0.0),
            batch_size: v.get("batch_size").and_then(Json::as_usize).unwrap_or(1),
            raw: v,
        })
    }

    /// Fetch the server's telemetry report.
    pub fn stats(&mut self) -> Result<String> {
        let v = self.round_trip(Json::object(vec![("cmd", Json::str("stats"))]))?;
        Ok(v.get("stats").and_then(Json::as_str).unwrap_or("").to_string())
    }

    /// Fetch the fleet report (errors when the server has no fleet).
    pub fn fleet_stats(&mut self) -> Result<Json> {
        let v = self.round_trip(Json::object(vec![("cmd", Json::str("fleet_stats"))]))?;
        v.get("fleet_stats").cloned().context("reply missing fleet_stats")
    }

    /// Fetch the autoscaler report (errors when the server has no
    /// fleet or no autoscaler).
    pub fn autoscale_stats(&mut self) -> Result<Json> {
        let v = self.round_trip(Json::object(vec![("cmd", Json::str("autoscale_stats"))]))?;
        v.get("autoscale_stats").cloned().context("reply missing autoscale_stats")
    }

    /// Fetch the fleet's metrics-registry snapshot (errors when the
    /// server has no fleet).
    pub fn metrics(&mut self) -> Result<Json> {
        let v = self.round_trip(Json::object(vec![("cmd", Json::str("metrics"))]))?;
        v.get("metrics").cloned().context("reply missing metrics")
    }

    /// Fetch the sampled request traces as Chrome trace-event JSON
    /// (errors when the server has no fleet; empty `traceEvents` when
    /// sampling is off).
    pub fn trace_dump(&mut self) -> Result<Json> {
        let v = self.round_trip(Json::object(vec![("cmd", Json::str("trace_dump"))]))?;
        v.get("trace").cloned().context("reply missing trace")
    }

    /// Ask the server to stop.
    pub fn quit(&mut self) -> Result<()> {
        let _ = self.round_trip(Json::object(vec![("cmd", Json::str("quit"))]))?;
        Ok(())
    }
}

/// `InferResponse` parsing helper shared with tests.
pub fn response_top1(resp: &InferResponse) -> usize {
    resp.top1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_seed_request() {
        let p = parse_request(r#"{"image_seed": 3, "precision": "imprecise"}"#, 12).unwrap();
        match p {
            Parsed::Infer { image, precision, with_sim, with_fleet, qos, model } => {
                assert_eq!(image.len(), crate::model::images::IMAGE_LEN);
                assert_eq!(precision, Precision::Imprecise);
                assert!(!with_sim);
                assert!(!with_fleet);
                assert_eq!(qos, Qos::default());
                assert_eq!(model, None);
            }
            _ => panic!("expected infer"),
        }
    }

    #[test]
    fn parses_model_field() {
        let p = parse_request(r#"{"image_seed": 1, "fleet": true, "model": "detector"}"#, 12)
            .unwrap();
        match p {
            Parsed::Infer { model, with_fleet, .. } => {
                assert_eq!(model.as_deref(), Some("detector"));
                assert!(with_fleet);
            }
            _ => panic!("expected infer"),
        }
        // a model without the fleet path is a visible error, as is a
        // non-string model
        assert!(parse_request(r#"{"image_seed": 1, "model": "detector"}"#, 12).is_err());
        assert!(parse_request(r#"{"image_seed": 1, "fleet": true, "model": 3}"#, 12).is_err());
    }

    #[test]
    fn parses_fleet_request() {
        let p = parse_request(r#"{"image_seed": 1, "fleet": true}"#, 12).unwrap();
        match p {
            Parsed::Infer { with_fleet, .. } => assert!(with_fleet),
            _ => panic!("expected infer"),
        }
    }

    #[test]
    fn parses_qos_fields() {
        let p = parse_request(
            r#"{"image_seed": 1, "fleet": true, "priority": 3, "deadline_ms": 450.5}"#,
            12,
        )
        .unwrap();
        match p {
            Parsed::Infer { qos, .. } => {
                assert_eq!(qos.priority, 3);
                assert_eq!(qos.deadline_ms, Some(450.5));
                assert!(qos.is_interactive());
            }
            _ => panic!("expected infer"),
        }
        // bulk is priority 0, no deadline
        let p = parse_request(r#"{"image_seed": 1, "priority": 0}"#, 12).unwrap();
        match p {
            Parsed::Infer { qos, .. } => assert_eq!(qos, Qos::bulk()),
            _ => panic!("expected infer"),
        }
        // malformed QoS is an error, not a silent default
        assert!(parse_request(r#"{"image_seed": 1, "priority": 300}"#, 12).is_err());
        assert!(parse_request(r#"{"image_seed": 1, "priority": "high"}"#, 12).is_err());
        assert!(parse_request(r#"{"image_seed": 1, "deadline_ms": -5}"#, 12).is_err());
        assert!(parse_request(r#"{"image_seed": 1, "deadline_ms": "soon"}"#, 12).is_err());
    }

    #[test]
    fn parses_raw_image_request() {
        let p = parse_request(r#"{"image": [0.1, 0.2, 0.3]}"#, 3).unwrap();
        match p {
            Parsed::Infer { image, .. } => assert_eq!(image, vec![0.1, 0.2, 0.3]),
            _ => panic!("expected infer"),
        }
    }

    #[test]
    fn rejects_bad_requests() {
        assert!(parse_request("not json", 3).is_err());
        assert!(parse_request(r#"{"image": [1.0]}"#, 3).is_err());
        assert!(parse_request(r#"{"precision": "half"}"#, 3).is_err());
        assert!(parse_request(r#"{"cmd": "dance"}"#, 3).is_err());
    }

    /// Seeded corruption of valid requests: every mutant must come
    /// back `Ok` or `Err` — a panic here is a crashed handler thread
    /// in production.  The LCG makes failures reproducible.
    #[test]
    fn seeded_bad_input_is_an_error_never_a_panic() {
        const ROUNDS: usize = 500;
        let seeds = [
            r#"{"image_seed": 3, "precision": "imprecise"}"#,
            r#"{"image_seed": 1, "fleet": true, "priority": 2, "deadline_ms": 500, "model": "m"}"#,
            r#"{"image": [0.1, 0.2, 0.3]}"#,
            r#"{"cmd": "metrics"}"#,
        ];
        let pool: Vec<char> = "{}[]\",:0123456789.eE+-truefalsnm ".chars().collect();
        let mut state: u64 = 0x00c0ffee;
        let mut rand = move |m: usize| -> usize {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((state >> 33) as usize) % m.max(1)
        };
        for _ in 0..ROUNDS {
            let base: Vec<char> = seeds[rand(seeds.len())].chars().collect();
            let mut mutant = base.clone();
            match rand(3) {
                0 => mutant.truncate(rand(base.len() + 1)),
                1 => {
                    let i = rand(base.len());
                    mutant[i] = pool[rand(pool.len())];
                }
                _ => {
                    let i = rand(base.len() + 1);
                    mutant.insert(i, pool[rand(pool.len())]);
                }
            }
            let text: String = mutant.into_iter().collect();
            let _ = parse_request(&text, 3); // Ok or Err both fine
        }
        // Known nasties are errors, not aborts: a bracket bomb burns
        // one parser stack frame per `[` without the depth guard.
        let bomb = format!("{{\"image\": {}", "[".repeat(100_000));
        assert!(parse_request(&bomb, 3).is_err(), "deep nesting is a visible error");
    }

    #[test]
    fn parses_commands() {
        assert!(matches!(parse_request(r#"{"cmd": "stats"}"#, 3).unwrap(), Parsed::Stats));
        assert!(matches!(
            parse_request(r#"{"cmd": "fleet_stats"}"#, 3).unwrap(),
            Parsed::FleetStats
        ));
        assert!(matches!(
            parse_request(r#"{"cmd": "autoscale_stats"}"#, 3).unwrap(),
            Parsed::AutoscaleStats
        ));
        assert!(matches!(parse_request(r#"{"cmd": "metrics"}"#, 3).unwrap(), Parsed::Metrics));
        assert!(matches!(
            parse_request(r#"{"cmd": "trace_dump"}"#, 3).unwrap(),
            Parsed::TraceDump
        ));
        assert!(matches!(parse_request(r#"{"cmd": "quit"}"#, 3).unwrap(), Parsed::Quit));
    }
}
