//! Layer-3 coordinator: the serving stack.
//!
//! ```text
//! client ──TCP/JSON──▶ server ──▶ Coordinator (router)
//!                                    │ per-precision queues
//!                                    ▼
//!                                 batcher (size/deadline policy)
//!                                    │ BatchJob
//!                                    ▼
//!                                 runtime thread (PJRT executors,
//!                                 weights resident; softmax+top-k)
//!                                    │ replies + telemetry
//! ```
//!
//! PJRT handles are not `Send`, so the runtime lives on a dedicated
//! thread that owns every executable; batching and routing are pure
//! queue logic and run on their own thread.  Python is never on this
//! path — executables were AOT-compiled by `make artifacts`.

pub mod admission;
pub mod batcher;
pub mod engine;
pub mod request;
pub mod ring;
pub mod scheduler;
pub mod server;
pub mod shard;
pub mod trace;

pub use batcher::{plan_batches, BatcherConfig};
pub use engine::{Coordinator, CoordinatorConfig};
pub use request::{InferRequest, InferResponse, Qos, SimEstimate};
pub use ring::HashRing;
pub use scheduler::PlanCache;
pub use shard::{Routed, ShardedFleet, ShardedReport};
