//! Plan cache: autotuned per-layer granularity plans, memoized per
//! (device, precision).
//!
//! This is the serving-side face of §III-D: the engine asks "what g
//! should layer L use on device D", the cache answers from one
//! autotuning pass.  The Rust vectorized execution path and the
//! simulated estimates both consume these plans, and the `autotune` CLI
//! command prints them (Table I).

use std::collections::HashMap;
use std::sync::Mutex;

use crate::model::graph::SqueezeNet;
use crate::util::sync::lock_unpoisoned;
use crate::simulator::autotune::{autotune_network, NetworkPlan};
use crate::simulator::device::{DeviceProfile, Precision};

/// Memoized autotuning results.
#[derive(Debug)]
pub struct PlanCache {
    net: SqueezeNet,
    plans: Mutex<HashMap<(&'static str, &'static str), NetworkPlan>>,
}

impl Default for PlanCache {
    fn default() -> Self {
        Self::new()
    }
}

impl PlanCache {
    pub fn new() -> Self {
        Self { net: SqueezeNet::v1_0(), plans: Mutex::new(HashMap::new()) }
    }

    /// The autotuned plan for (device, precision); computed on first use.
    ///
    /// The sweep runs *outside* the lock: first-touch autotunes for
    /// different (device, precision) keys proceed concurrently instead
    /// of serializing behind one mutex.  A double-checked insert keeps
    /// exactly one winner per key (a losing racer's duplicate work is
    /// discarded — autotuning is deterministic, so both are identical).
    pub fn plan(&self, device: &DeviceProfile, precision: Precision) -> NetworkPlan {
        let key = (device.id, precision.label());
        if let Some(plan) = lock_unpoisoned(&self.plans).get(&key) {
            return plan.clone();
        }
        let plan = autotune_network(&self.net, precision, device);
        let mut plans = lock_unpoisoned(&self.plans);
        plans.entry(key).or_insert(plan).clone()
    }

    /// Layer-name → optimal-g map for the Rust vectorized engine.
    pub fn plan_map(&self, device: &DeviceProfile, precision: Precision) -> HashMap<String, usize> {
        self.plan(device, precision).as_plan_map()
    }

    /// Number of cached plans (for tests).
    pub fn cached(&self) -> usize {
        lock_unpoisoned(&self.plans).len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn memoizes_per_device_and_precision() {
        let cache = PlanCache::new();
        let s7 = DeviceProfile::galaxy_s7();
        let p1 = cache.plan(&s7, Precision::Precise);
        let p2 = cache.plan(&s7, Precision::Precise);
        assert_eq!(cache.cached(), 1);
        assert_eq!(p1.optimal_g("conv1"), p2.optimal_g("conv1"));
        cache.plan(&s7, Precision::Imprecise);
        cache.plan(&DeviceProfile::nexus_5(), Precision::Precise);
        assert_eq!(cache.cached(), 3);
    }

    #[test]
    fn concurrent_first_touch_is_consistent() {
        // Many threads hit the cold cache for *different* devices and
        // precisions at once.  Every thread must get the same plan the
        // sequential path computes, and each key is cached exactly once.
        let cache = PlanCache::new();
        let combos: Vec<(DeviceProfile, Precision)> = DeviceProfile::all()
            .into_iter()
            .flat_map(|d| {
                [(d.clone(), Precision::Precise), (d, Precision::Imprecise)]
            })
            .collect();
        std::thread::scope(|s| {
            for _ in 0..4 {
                for (device, precision) in &combos {
                    let cache = &cache;
                    s.spawn(move || {
                        let plan = cache.plan(device, *precision);
                        let expected = crate::simulator::autotune::autotune_network(
                            &SqueezeNet::v1_0(),
                            *precision,
                            device,
                        );
                        for spec in SqueezeNet::v1_0().conv_layers() {
                            assert_eq!(
                                plan.optimal_g(&spec.name),
                                expected.optimal_g(&spec.name),
                                "{} {} {}",
                                device.id,
                                precision.label(),
                                spec.name
                            );
                        }
                    });
                }
            }
        });
        assert_eq!(cache.cached(), combos.len());
    }

    #[test]
    fn plans_respect_divisibility() {
        let cache = PlanCache::new();
        let map = cache.plan_map(&DeviceProfile::nexus_6p(), Precision::Precise);
        for spec in SqueezeNet::v1_0().conv_layers() {
            let g = map[&spec.name];
            assert_eq!(spec.cout % g, 0);
            assert_eq!((spec.cout / g) % 4, 0);
        }
    }
}
