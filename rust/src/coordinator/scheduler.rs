//! Plan cache: autotuned per-layer granularity plans, memoized per
//! (device, precision).
//!
//! This is the serving-side face of §III-D: the engine asks "what g
//! should layer L use on device D", the cache answers from one
//! autotuning pass.  The Rust vectorized execution path and the
//! simulated estimates both consume these plans, and the `autotune` CLI
//! command prints them (Table I).

use std::collections::HashMap;
use std::sync::Mutex;

use crate::model::graph::SqueezeNet;
use crate::simulator::autotune::{autotune_network, NetworkPlan};
use crate::simulator::device::{DeviceProfile, Precision};

/// Memoized autotuning results.
pub struct PlanCache {
    net: SqueezeNet,
    plans: Mutex<HashMap<(&'static str, &'static str), NetworkPlan>>,
}

impl Default for PlanCache {
    fn default() -> Self {
        Self::new()
    }
}

impl PlanCache {
    pub fn new() -> Self {
        Self { net: SqueezeNet::v1_0(), plans: Mutex::new(HashMap::new()) }
    }

    /// The autotuned plan for (device, precision); computed on first use.
    pub fn plan(&self, device: &DeviceProfile, precision: Precision) -> NetworkPlan {
        let key = (device.id, precision.label());
        let mut plans = self.plans.lock().unwrap();
        plans
            .entry(key)
            .or_insert_with(|| autotune_network(&self.net, precision, device))
            .clone()
    }

    /// Layer-name → optimal-g map for the Rust vectorized engine.
    pub fn plan_map(&self, device: &DeviceProfile, precision: Precision) -> HashMap<String, usize> {
        self.plan(device, precision).as_plan_map()
    }

    /// Number of cached plans (for tests).
    pub fn cached(&self) -> usize {
        self.plans.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn memoizes_per_device_and_precision() {
        let cache = PlanCache::new();
        let s7 = DeviceProfile::galaxy_s7();
        let p1 = cache.plan(&s7, Precision::Precise);
        let p2 = cache.plan(&s7, Precision::Precise);
        assert_eq!(cache.cached(), 1);
        assert_eq!(p1.optimal_g("conv1"), p2.optimal_g("conv1"));
        cache.plan(&s7, Precision::Imprecise);
        cache.plan(&DeviceProfile::nexus_5(), Precision::Precise);
        assert_eq!(cache.cached(), 3);
    }

    #[test]
    fn plans_respect_divisibility() {
        let cache = PlanCache::new();
        let map = cache.plan_map(&DeviceProfile::nexus_6p(), Precision::Precise);
        for spec in SqueezeNet::v1_0().conv_layers() {
            let g = map[&spec.name];
            assert_eq!(spec.cout % g, 0);
            assert_eq!((spec.cout / g) % 4, 0);
        }
    }
}
