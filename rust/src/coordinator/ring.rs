//! Consistent-hash ring with virtual nodes for the sharded front door.
//!
//! The sharded coordinator ([`super::shard`]) routes every request by
//! its `(tenant, model)` key onto one of M shards.  A naive
//! `hash(key) % M` would reshuffle almost *every* key when M changes;
//! the classic consistent-hashing fix places `vnodes_per_shard`
//! pseudo-random points per shard on a `u64` ring and assigns a key to
//! the owner of the first point at or clockwise after `hash(key)`.
//!
//! Redistribution guarantees (asserted by the seeded property tests
//! below and re-checked at fleet scale in `benches/fleet_sharded.rs`):
//!
//! - **join**: only keys captured by the *new* shard's points move —
//!   an expected `1/M_new` of the keyspace, which is the theoretical
//!   minimum for a balanced ring.  *Collateral* movement (a key
//!   hopping between two pre-existing shards) is exactly zero, far
//!   under the <5% budget the front-door design allows;
//! - **leave**: only the leaver's own keys move (they fall to the next
//!   point clockwise); keys on surviving shards never move at all.
//!
//! The ring is plain data — no clocks, no locks, no I/O — so it can be
//! exercised deterministically from tests and benches.  All lookups
//! are panic-free (`binary_search` + `get`), keeping the coordinator
//! inside the repo's ratcheted panic budget.

use crate::runtime::artifacts::ModelId;

/// Default virtual nodes per shard.  64 points keep the max/mean load
/// skew under ~1.3x for small M while `add_shard`/`remove_shard` stay
/// O(vnodes · log points).
pub const DEFAULT_VNODES: usize = 64;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// FNV-1a over `bytes`, chained from `state` so multi-field keys can
/// be hashed incrementally with separators.
fn fnv1a(state: u64, bytes: &[u8]) -> u64 {
    let mut h = state;
    for b in bytes {
        h ^= u64::from(*b);
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Position of a request key on the ring.  Tenant and model are
/// length-prefixed so `("ab", m)` and `("a", "b"-ish)` cannot collide
/// structurally; an absent tenant hashes distinctly from `Some("")`.
pub fn route_point(tenant: Option<&str>, model: ModelId) -> u64 {
    let mut h = FNV_OFFSET;
    match tenant {
        Some(t) => {
            h = fnv1a(h, &[1u8]);
            h = fnv1a(h, &(t.len() as u64).to_le_bytes());
            h = fnv1a(h, t.as_bytes());
        }
        None => h = fnv1a(h, &[0u8]),
    }
    fnv1a(h, &model.0.to_le_bytes())
}

/// Position of shard `shard`'s `vnode`-th virtual node.
fn vnode_point(shard: usize, vnode: usize) -> u64 {
    let mut h = fnv1a(FNV_OFFSET, &(shard as u64).to_le_bytes());
    h = fnv1a(h, &[0xfe]);
    fnv1a(h, &(vnode as u64).to_le_bytes())
}

/// See the module docs.
#[derive(Debug, Clone)]
pub struct HashRing {
    vnodes_per_shard: usize,
    /// `(point, shard)` sorted by point; ties (astronomically rare)
    /// resolve to the lower shard id, deterministically.
    points: Vec<(u64, usize)>,
}

impl HashRing {
    /// A ring over shards `0..shards`.
    pub fn new(shards: usize, vnodes_per_shard: usize) -> HashRing {
        let mut ring = HashRing { vnodes_per_shard: vnodes_per_shard.max(1), points: Vec::new() };
        for s in 0..shards {
            ring.add_shard(s);
        }
        ring
    }

    /// Number of distinct shards on the ring.
    pub fn shard_count(&self) -> usize {
        self.points.len() / self.vnodes_per_shard
    }

    /// True when no shard is on the ring (every lookup returns `None`).
    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    pub fn contains(&self, shard: usize) -> bool {
        self.points.iter().any(|&(_, s)| s == shard)
    }

    /// Shard ids currently on the ring, ascending.
    pub fn shards(&self) -> Vec<usize> {
        let mut ids: Vec<usize> = self.points.iter().map(|&(_, s)| s).collect();
        ids.sort_unstable();
        ids.dedup();
        ids
    }

    /// Place `shard`'s virtual nodes on the ring (idempotent).
    pub fn add_shard(&mut self, shard: usize) {
        if self.contains(shard) {
            return;
        }
        for v in 0..self.vnodes_per_shard {
            let entry = (vnode_point(shard, v), shard);
            let at = self.points.partition_point(|p| *p < entry);
            self.points.insert(at, entry);
        }
    }

    /// Remove `shard`'s virtual nodes; its keys fall clockwise to the
    /// survivors, which keep every key they already owned.
    pub fn remove_shard(&mut self, shard: usize) {
        self.points.retain(|&(_, s)| s != shard);
    }

    /// Owner of an already-hashed ring position.
    pub fn shard_for_point(&self, point: u64) -> Option<usize> {
        let at = self.points.partition_point(|&(p, _)| p < point);
        self.points.get(at).or_else(|| self.points.first()).map(|&(_, s)| s)
    }

    /// Owner of the `(tenant, model)` routing key.
    pub fn shard_for(&self, tenant: Option<&str>, model: ModelId) -> Option<usize> {
        self.shard_for_point(route_point(tenant, model))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    /// Deterministic key population: a mix of anonymous and named
    /// tenants across a handful of models, driven by a seeded LCG.
    fn keys(n: usize, seed: u64) -> Vec<(Option<String>, ModelId)> {
        let mut state = seed.wrapping_mul(0x9e37_79b9_7f4a_7c15) | 1;
        let mut next = move || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            state >> 33
        };
        (0..n)
            .map(|_| {
                let tenant = match next() % 4 {
                    0 => None,
                    _ => Some(format!("tenant-{}", next() % 997)),
                };
                (tenant, ModelId((next() % 6) as u16))
            })
            .collect()
    }

    fn assign(ring: &HashRing, ks: &[(Option<String>, ModelId)]) -> Vec<usize> {
        ks.iter().map(|(t, m)| ring.shard_for(t.as_deref(), *m).unwrap()).collect()
    }

    #[test]
    fn empty_ring_routes_nowhere() {
        let ring = HashRing::new(0, DEFAULT_VNODES);
        assert!(ring.is_empty());
        assert_eq!(ring.shard_for(None, ModelId::DEFAULT), None);
    }

    #[test]
    fn routing_is_deterministic_and_total() {
        let ring = HashRing::new(4, DEFAULT_VNODES);
        for (t, m) in keys(500, 7) {
            let a = ring.shard_for(t.as_deref(), m).unwrap();
            let b = ring.shard_for(t.as_deref(), m).unwrap();
            assert_eq!(a, b);
            assert!(a < 4);
        }
    }

    #[test]
    fn add_is_idempotent_and_remove_inverts() {
        let mut ring = HashRing::new(3, 16);
        let before = ring.points.clone();
        ring.add_shard(1);
        assert_eq!(ring.points, before, "re-adding an existing shard is a no-op");
        ring.add_shard(3);
        ring.remove_shard(3);
        assert_eq!(ring.points, before, "add then remove restores the ring");
    }

    #[test]
    fn load_is_roughly_balanced() {
        let ring = HashRing::new(4, DEFAULT_VNODES);
        let ks = keys(20_000, 42);
        let mut per: BTreeMap<usize, usize> = BTreeMap::new();
        for s in assign(&ring, &ks) {
            *per.entry(s).or_insert(0) += 1;
        }
        assert_eq!(per.len(), 4, "every shard owns keys: {per:?}");
        let max = *per.values().max().unwrap() as f64;
        let mean = ks.len() as f64 / 4.0;
        assert!(max / mean < 1.8, "load skew too high: {per:?}");
    }

    /// The tentpole redistribution property, seeded: join moves only
    /// keys *to* the joiner (≈1/M_new of them, the minimum), leave
    /// moves only the leaver's keys — collateral movement between
    /// surviving shards is exactly zero, <5% by a wide margin.
    #[test]
    fn join_and_leave_move_under_five_percent_collateral() {
        for seed in [1u64, 42, 1337] {
            let ks = keys(10_000, seed);
            let mut ring = HashRing::new(4, DEFAULT_VNODES);
            let before = assign(&ring, &ks);

            ring.add_shard(4);
            let joined = assign(&ring, &ks);
            let moved = before.iter().zip(&joined).filter(|(a, b)| a != b).count();
            let collateral =
                before.iter().zip(&joined).filter(|(a, b)| a != b && **b != 4).count();
            assert_eq!(collateral, 0, "join moved keys between old shards (seed {seed})");
            let frac = moved as f64 / ks.len() as f64;
            assert!(
                (0.10..0.35).contains(&frac),
                "join should move ~1/5 of keys, got {frac:.3} (seed {seed})"
            );

            ring.remove_shard(4);
            let left = assign(&ring, &ks);
            assert_eq!(left, before, "leave must restore the pre-join assignment");
            let stayed = joined
                .iter()
                .zip(&left)
                .filter(|(was, now)| **was != 4 && was != now)
                .count();
            assert_eq!(stayed, 0, "leave moved a surviving shard's keys (seed {seed})");
        }
    }
}
