//! Workload trace generation and replay — the load-generation substrate
//! for serving experiments (arrival processes the paper's successor
//! evaluations use: open-loop Poisson, bursts, diurnal ramps).
//!
//! A [`Trace`] is a deterministic list of (arrival offset, image index,
//! precision) tuples; [`replay`] drives a [`Coordinator`] with it in
//! open loop and reports the achieved latency distribution.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::model::ImageCorpus;
use crate::runtime::artifacts::ModelId;
use crate::simulator::device::Precision;
use crate::util::rng::Rng;

use super::engine::Coordinator;
use super::request::Qos;

/// Arrival process shapes.
#[derive(Debug, Clone, Copy)]
pub enum Arrival {
    /// Fixed inter-arrival gap.
    Uniform { rate_per_s: f64 },
    /// Exponential inter-arrivals (Poisson process).
    Poisson { rate_per_s: f64 },
    /// Poisson base load with periodic multiplicative bursts.
    Bursty { rate_per_s: f64, burst_every: usize, burst_len: usize, burst_mult: f64 },
}

/// One request of a trace.
#[derive(Debug, Clone, Copy)]
pub struct TraceEntry {
    /// Arrival time offset from trace start.
    pub at: Duration,
    /// Corpus image index.
    pub image: u64,
    pub precision: Precision,
    /// QoS class the request carries into dispatch (default class
    /// unless the trace was given a mix — see [`Trace::with_qos_mix`]).
    pub qos: Qos,
    /// Catalog model the request serves (the default model unless the
    /// trace was given a mix — see [`Trace::with_model_mix`]; ignored
    /// by fleets without an artifact tier).
    pub model: ModelId,
}

/// A deterministic workload trace.
#[derive(Debug, Clone)]
pub struct Trace {
    pub entries: Vec<TraceEntry>,
    pub seed: u64,
}

/// Sample one inter-arrival gap (seconds) for request `i` of a
/// process — shared by [`Trace::generate`] and [`Trace::phases`].
fn sample_gap(arrival: Arrival, i: usize, rng: &mut Rng) -> f64 {
    match arrival {
        Arrival::Uniform { rate_per_s } => 1.0 / rate_per_s,
        Arrival::Poisson { rate_per_s } => {
            // inverse-CDF exponential sample
            -(1.0 - rng.next_f64()).ln() / rate_per_s
        }
        Arrival::Bursty { rate_per_s, burst_every, burst_len, burst_mult } => {
            let in_burst = burst_every > 0 && (i % burst_every) < burst_len;
            let rate = if in_burst { rate_per_s * burst_mult } else { rate_per_s };
            -(1.0 - rng.next_f64()).ln() / rate
        }
    }
}

impl Trace {
    /// Generate `n` arrivals with the given process; `imprecise_frac`
    /// of requests (deterministically chosen) use the imprecise path.
    /// (A one-segment [`phases`](Self::phases) trace — same RNG
    /// stream, so existing seeds keep their exact timelines.)
    pub fn generate(n: usize, arrival: Arrival, imprecise_frac: f64, seed: u64) -> Trace {
        Self::phases(&[(n, arrival)], imprecise_frac, seed)
    }

    /// Generate a multi-phase trace: each `(n, arrival)` segment
    /// continues from where the previous one left off, so traffic
    /// ramps and spikes (calm -> surge -> calm) are one deterministic
    /// timeline — the workload shape autoscaling experiments need.
    pub fn phases(segments: &[(usize, Arrival)], imprecise_frac: f64, seed: u64) -> Trace {
        let mut rng = Rng::new(seed);
        let mut t = 0.0f64;
        let total: usize = segments.iter().map(|(n, _)| n).sum();
        let mut entries = Vec::with_capacity(total);
        for &(n, arrival) in segments {
            for i in 0..n {
                t += sample_gap(arrival, i, &mut rng);
                let precision = if rng.next_f64() < imprecise_frac {
                    Precision::Imprecise
                } else {
                    Precision::Precise
                };
                entries.push(TraceEntry {
                    at: Duration::from_secs_f64(t),
                    image: entries.len() as u64,
                    precision,
                    qos: Qos::default(),
                    model: ModelId::DEFAULT,
                });
            }
        }
        Trace { entries, seed }
    }

    /// Set every entry's QoS class (e.g. mark the whole trace bulk
    /// before layering an interactive slice on top with
    /// [`with_qos_mix`](Self::with_qos_mix)).
    pub fn with_base_qos(mut self, qos: Qos) -> Trace {
        for e in &mut self.entries {
            e.qos = qos;
        }
        self
    }

    /// Mark a deterministic fraction of arrivals with `qos` — the
    /// interactive slice of a mixed trace; the rest keep the class
    /// they already have.  The assignment derives from the trace seed
    /// (independently of the arrival stream), so a given (trace, mix)
    /// is fully reproducible.
    pub fn with_qos_mix(mut self, frac: f64, qos: Qos) -> Trace {
        assert!((0.0..=1.0).contains(&frac), "qos mix fraction must be in [0, 1]");
        let mut rng = Rng::new(self.seed ^ 0xA5A5_5A5A_C0FF_EE00);
        for e in &mut self.entries {
            if rng.next_f64() < frac {
                e.qos = qos;
            }
        }
        self
    }

    /// Mark a deterministic fraction of arrivals as serving `model` —
    /// the second-model slice of a multi-model trace; the rest keep
    /// the model they already have.  Like
    /// [`with_qos_mix`](Self::with_qos_mix) the assignment derives
    /// from the trace seed (its own stream, independent of both the
    /// arrival process and the QoS mix), so a given (trace, mix) is
    /// fully reproducible and the two mixes compose freely.
    pub fn with_model_mix(mut self, frac: f64, model: ModelId) -> Trace {
        assert!((0.0..=1.0).contains(&frac), "model mix fraction must be in [0, 1]");
        let mut rng = Rng::new(self.seed ^ 0x0DE1_CA7E_D0_C0FF_EE);
        for e in &mut self.entries {
            if rng.next_f64() < frac {
                e.model = model;
            }
        }
        self
    }

    /// Total span of the trace.
    pub fn span(&self) -> Duration {
        self.entries.last().map(|e| e.at).unwrap_or_default()
    }

    /// Offered load in requests/second.
    pub fn offered_rate(&self) -> f64 {
        if self.entries.len() < 2 {
            return 0.0;
        }
        self.entries.len() as f64 / self.span().as_secs_f64()
    }
}

/// Replay outcome.
#[derive(Debug, Clone)]
pub struct ReplayReport {
    pub completed: usize,
    pub errors: usize,
    pub wall: Duration,
    /// Sorted end-to-end latencies (ms).
    pub latencies_ms: Vec<f64>,
    pub achieved_rate: f64,
}

impl ReplayReport {
    pub fn percentile_ms(&self, p: f64) -> f64 {
        if self.latencies_ms.is_empty() {
            return 0.0;
        }
        self.latencies_ms[((self.latencies_ms.len() - 1) as f64 * p) as usize]
    }

    pub fn summary(&self) -> String {
        format!(
            "{} completed, {} errors in {:.2} s -> {:.1} req/s; latency p50 {:.1} ms p95 {:.1} ms p99 {:.1} ms",
            self.completed,
            self.errors,
            self.wall.as_secs_f64(),
            self.achieved_rate,
            self.percentile_ms(0.50),
            self.percentile_ms(0.95),
            self.percentile_ms(0.99),
        )
    }
}

/// Open-loop replay: arrivals are honored on schedule regardless of
/// completions (the correct way to measure a serving system under
/// load), responses are collected asynchronously.
pub fn replay(coordinator: &Arc<Coordinator>, trace: &Trace, corpus: &ImageCorpus) -> Result<ReplayReport> {
    let start = Instant::now();
    let mut pending = Vec::with_capacity(trace.entries.len());
    for entry in &trace.entries {
        if let Some(wait) = entry.at.checked_sub(start.elapsed()) {
            if !wait.is_zero() {
                std::thread::sleep(wait);
            }
        }
        let rx = coordinator.submit(corpus.image(entry.image), entry.precision, false)?;
        pending.push((Instant::now(), rx));
    }
    let mut latencies = Vec::with_capacity(pending.len());
    let mut errors = 0usize;
    for (_, rx) in pending {
        match rx.recv() {
            Ok(Ok(resp)) => latencies.push(resp.latency.as_secs_f64() * 1e3),
            _ => errors += 1,
        }
    }
    let wall = start.elapsed();
    latencies.sort_by(f64::total_cmp);
    Ok(ReplayReport {
        completed: latencies.len(),
        errors,
        achieved_rate: latencies.len() as f64 / wall.as_secs_f64(),
        wall,
        latencies_ms: latencies,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_is_deterministic_and_ordered() {
        let a = Trace::generate(50, Arrival::Poisson { rate_per_s: 100.0 }, 0.5, 9);
        let b = Trace::generate(50, Arrival::Poisson { rate_per_s: 100.0 }, 0.5, 9);
        assert_eq!(a.entries.len(), 50);
        for (x, y) in a.entries.iter().zip(&b.entries) {
            assert_eq!(x.at, y.at);
            assert_eq!(x.precision, y.precision);
        }
        // strictly increasing arrivals
        assert!(a.entries.windows(2).all(|w| w[0].at < w[1].at));
        // different seeds differ
        let c = Trace::generate(50, Arrival::Poisson { rate_per_s: 100.0 }, 0.5, 10);
        assert!(a.entries.iter().zip(&c.entries).any(|(x, y)| x.at != y.at));
    }

    #[test]
    fn uniform_rate_is_exact() {
        let t = Trace::generate(100, Arrival::Uniform { rate_per_s: 200.0 }, 0.0, 1);
        assert!((t.offered_rate() - 200.0).abs() < 1.0, "{}", t.offered_rate());
        assert!(t.entries.iter().all(|e| e.precision == Precision::Precise));
    }

    #[test]
    fn poisson_rate_approximates_target() {
        let t = Trace::generate(2000, Arrival::Poisson { rate_per_s: 50.0 }, 1.0, 3);
        let rate = t.offered_rate();
        assert!((35.0..70.0).contains(&rate), "rate {rate}");
        assert!(t.entries.iter().all(|e| e.precision == Precision::Imprecise));
    }

    #[test]
    fn bursts_raise_local_rate() {
        let t = Trace::generate(
            400,
            Arrival::Bursty { rate_per_s: 50.0, burst_every: 100, burst_len: 50, burst_mult: 10.0 },
            0.0,
            4,
        );
        // bursty trace must be shorter than a pure-poisson one at the
        // same base rate (some arrivals are 10x faster)
        let p = Trace::generate(400, Arrival::Poisson { rate_per_s: 50.0 }, 0.0, 4);
        assert!(t.span() < p.span());
    }

    #[test]
    fn phases_concatenate_and_shift_rate() {
        let t = Trace::phases(
            &[
                (50, Arrival::Uniform { rate_per_s: 5.0 }),
                (100, Arrival::Uniform { rate_per_s: 50.0 }),
                (50, Arrival::Uniform { rate_per_s: 5.0 }),
            ],
            0.0,
            7,
        );
        assert_eq!(t.entries.len(), 200);
        // strictly increasing arrivals across segment boundaries
        assert!(t.entries.windows(2).all(|w| w[0].at < w[1].at));
        // image ids are the global arrival order
        assert_eq!(t.entries[199].image, 199);
        // the middle segment is 10x denser: 100 arrivals in ~2 s vs
        // 50 in ~10 s on either side
        let span_mid = t.entries[149].at - t.entries[50].at;
        let span_head = t.entries[49].at - t.entries[0].at;
        assert!(span_mid < span_head, "{span_mid:?} vs {span_head:?}");
        // deterministic per seed
        let u = Trace::phases(
            &[
                (50, Arrival::Uniform { rate_per_s: 5.0 }),
                (100, Arrival::Uniform { rate_per_s: 50.0 }),
                (50, Arrival::Uniform { rate_per_s: 5.0 }),
            ],
            0.0,
            7,
        );
        assert_eq!(t.entries.len(), u.entries.len());
        assert!(t.entries.iter().zip(&u.entries).all(|(a, b)| a.at == b.at));
    }

    #[test]
    fn qos_mix_is_deterministic_and_respects_fraction() {
        let mk = || {
            Trace::generate(1000, Arrival::Poisson { rate_per_s: 50.0 }, 0.0, 9)
                .with_base_qos(Qos::bulk())
                .with_qos_mix(0.3, Qos::interactive(2, 500.0))
        };
        let a = mk();
        let b = mk();
        // deterministic per seed, down to each entry's class
        assert!(a.entries.iter().zip(&b.entries).all(|(x, y)| x.qos == y.qos));
        let hi = a.entries.iter().filter(|e| e.qos.is_interactive()).count() as f64 / 1000.0;
        assert!((0.2..0.4).contains(&hi), "interactive fraction {hi}");
        // the rest kept the bulk base class
        assert!(a
            .entries
            .iter()
            .all(|e| e.qos.is_interactive() || e.qos == Qos::bulk()));
        // the arrival timeline is untouched by the mix
        let plain = Trace::generate(1000, Arrival::Poisson { rate_per_s: 50.0 }, 0.0, 9);
        assert!(a.entries.iter().zip(&plain.entries).all(|(x, y)| x.at == y.at));
        // default traces carry the default class
        assert!(plain.entries.iter().all(|e| e.qos == Qos::default()));
    }

    #[test]
    fn model_mix_is_deterministic_and_independent_of_qos_mix() {
        let det = ModelId(1);
        let mk = || {
            Trace::generate(1000, Arrival::Poisson { rate_per_s: 50.0 }, 0.0, 9)
                .with_base_qos(Qos::bulk())
                .with_qos_mix(0.3, Qos::interactive(2, 500.0))
                .with_model_mix(0.5, det)
        };
        let a = mk();
        let b = mk();
        assert!(a.entries.iter().zip(&b.entries).all(|(x, y)| x.model == y.model));
        let frac =
            a.entries.iter().filter(|e| e.model == det).count() as f64 / 1000.0;
        assert!((0.4..0.6).contains(&frac), "model fraction {frac}");
        // the model mix leaves arrivals and QoS classes untouched
        let plain = Trace::generate(1000, Arrival::Poisson { rate_per_s: 50.0 }, 0.0, 9)
            .with_base_qos(Qos::bulk())
            .with_qos_mix(0.3, Qos::interactive(2, 500.0));
        assert!(a.entries.iter().zip(&plain.entries).all(|(x, y)| x.at == y.at));
        assert!(a.entries.iter().zip(&plain.entries).all(|(x, y)| x.qos == y.qos));
        // default traces serve the default model
        assert!(plain.entries.iter().all(|e| e.model == ModelId::DEFAULT));
        // the model and QoS slices are independent streams: the
        // detector slice contains both bulk and interactive riders
        assert!(a.entries.iter().any(|e| e.model == det && e.qos.is_interactive()));
        assert!(a.entries.iter().any(|e| e.model == det && !e.qos.is_interactive()));
    }

    #[test]
    fn imprecise_fraction_respected() {
        let t = Trace::generate(1000, Arrival::Uniform { rate_per_s: 10.0 }, 0.3, 5);
        let frac = t.entries.iter().filter(|e| e.precision == Precision::Imprecise).count() as f64
            / 1000.0;
        assert!((0.2..0.4).contains(&frac), "fraction {frac}");
    }
}
