//! Request/response types of the serving API.

use std::time::{Duration, Instant};

use crate::simulator::device::Precision;
use crate::util::json::Json;

/// Per-request quality-of-service class, threaded end to end through
/// the serving path: parsed from the TCP JSON (`"priority"`,
/// `"deadline_ms"`), carried by trace entries, and honored by the
/// fleet's admission gate, routers, and replica batchers.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Qos {
    /// Scheduling priority; higher is more important.  The default
    /// ([`Qos::DEFAULT_PRIORITY`]) reproduces the pre-QoS behavior
    /// exactly; `0` marks bulk traffic whose latency is nearly free to
    /// trade away (it is also the first to be shed under pressure).
    pub priority: u8,
    /// Relative deadline: the latency budget in milliseconds from
    /// arrival (virtual time on the fleet path, wall clock on the live
    /// server).  `None` = no deadline.
    pub deadline_ms: Option<f64>,
}

impl Default for Qos {
    fn default() -> Qos {
        Qos { priority: Qos::DEFAULT_PRIORITY, deadline_ms: None }
    }
}

impl Qos {
    /// The neutral priority every request gets unless it asks for
    /// something else.  Priorities below it are bulk; above it (or any
    /// deadline) mark the interactive class.
    pub const DEFAULT_PRIORITY: u8 = 1;

    /// Bulk batch traffic: lowest priority, no deadline — sheds first,
    /// tolerates unbounded queueing on the cheapest replicas.
    pub fn bulk() -> Qos {
        Qos { priority: 0, deadline_ms: None }
    }

    /// Interactive traffic: raised priority plus a latency budget in
    /// milliseconds from arrival.
    pub fn interactive(priority: u8, deadline_ms: f64) -> Qos {
        Qos { priority, deadline_ms: Some(deadline_ms) }
    }

    /// Does this request belong to the interactive class (raised
    /// priority or an explicit deadline)?  The autoscaler splits its
    /// p95 breach signal on this, so bulk traffic cannot mask
    /// interactive SLO violations.
    pub fn is_interactive(&self) -> bool {
        self.priority > Qos::DEFAULT_PRIORITY || self.deadline_ms.is_some()
    }

    /// Reject budgets the dispatch path cannot honor.
    pub fn validate(&self) -> Result<(), String> {
        match self.deadline_ms {
            Some(d) if !(d.is_finite() && d > 0.0) => {
                Err(format!("deadline_ms must be a positive number, got {d}"))
            }
            _ => Ok(()),
        }
    }
}

/// An inference request entering the coordinator.
#[derive(Debug, Clone)]
pub struct InferRequest {
    pub id: u64,
    /// NHWC image, `224*224*3` f32.
    pub image: Vec<f32>,
    pub precision: Precision,
    /// Include simulated mobile-device latency/energy estimates.
    pub with_sim: bool,
    /// QoS class (recorded on the single-device path, enforced on the
    /// fleet path).
    pub qos: Qos,
    pub enqueued_at: Instant,
}

/// Simulated execution estimate on one mobile device profile
/// (the paper's evaluation target, attached to real inferences).
#[derive(Debug, Clone)]
pub struct SimEstimate {
    pub device: &'static str,
    pub latency_ms: f64,
    pub energy_j: f64,
}

/// The response for one request.
#[derive(Debug, Clone)]
pub struct InferResponse {
    pub id: u64,
    /// Argmax class.
    pub top1: usize,
    /// Top-5 (class, probability).
    pub top5: Vec<(usize, f32)>,
    /// End-to-end latency inside the coordinator.
    pub latency: Duration,
    /// Time spent queued before the batch formed.
    pub queue_time: Duration,
    /// Size of the batch this request rode in.
    pub batch_size: usize,
    pub precision: Precision,
    /// Present when the request asked for simulation.
    pub sim: Vec<SimEstimate>,
}

impl InferResponse {
    /// Wire representation (JSON object) for the TCP server.
    pub fn to_json(&self) -> Json {
        Json::object(vec![
            ("id", Json::num(self.id as f64)),
            ("top1", Json::num(self.top1 as f64)),
            (
                "top5",
                Json::Array(
                    self.top5
                        .iter()
                        .map(|(c, p)| {
                            Json::object(vec![
                                ("class", Json::num(*c as f64)),
                                ("prob", Json::num(*p as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("latency_ms", Json::num(self.latency.as_secs_f64() * 1e3)),
            ("queue_ms", Json::num(self.queue_time.as_secs_f64() * 1e3)),
            ("batch_size", Json::num(self.batch_size as f64)),
            ("precision", Json::str(self.precision.label())),
            (
                "sim",
                Json::Array(
                    self.sim
                        .iter()
                        .map(|s| {
                            Json::object(vec![
                                ("device", Json::str(s.device)),
                                ("latency_ms", Json::num(s.latency_ms)),
                                ("energy_j", Json::num(s.energy_j)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qos_defaults_and_classes() {
        let q = Qos::default();
        assert_eq!(q.priority, Qos::DEFAULT_PRIORITY);
        assert_eq!(q.deadline_ms, None);
        assert!(!q.is_interactive(), "the default class is not interactive");
        assert!(q.validate().is_ok());
        assert!(!Qos::bulk().is_interactive());
        assert!(Qos::bulk().priority < Qos::DEFAULT_PRIORITY);
        let i = Qos::interactive(2, 500.0);
        assert!(i.is_interactive());
        assert!(i.validate().is_ok());
        // a deadline alone is interactive, even at default priority
        assert!(Qos { priority: Qos::DEFAULT_PRIORITY, deadline_ms: Some(100.0) }
            .is_interactive());
        // non-positive or non-finite budgets are rejected
        assert!(Qos { priority: 1, deadline_ms: Some(0.0) }.validate().is_err());
        assert!(Qos { priority: 1, deadline_ms: Some(-5.0) }.validate().is_err());
        assert!(Qos { priority: 1, deadline_ms: Some(f64::NAN) }.validate().is_err());
    }

    #[test]
    fn response_serializes() {
        let r = InferResponse {
            id: 3,
            top1: 7,
            top5: vec![(7, 0.9), (1, 0.05)],
            latency: Duration::from_millis(12),
            queue_time: Duration::from_millis(2),
            batch_size: 4,
            precision: Precision::Precise,
            sim: vec![SimEstimate { device: "Nexus 5", latency_ms: 141.0, energy_j: 0.1 }],
        };
        let j = r.to_json();
        assert_eq!(j.get("top1").unwrap().as_usize(), Some(7));
        assert_eq!(j.get("batch_size").unwrap().as_usize(), Some(4));
        let text = j.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.get("precision").unwrap().as_str(), Some("precise"));
    }
}
