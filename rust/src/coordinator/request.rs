//! Request/response types of the serving API.

use std::time::{Duration, Instant};

use crate::simulator::device::Precision;
use crate::util::json::Json;

/// An inference request entering the coordinator.
#[derive(Debug, Clone)]
pub struct InferRequest {
    pub id: u64,
    /// NHWC image, `224*224*3` f32.
    pub image: Vec<f32>,
    pub precision: Precision,
    /// Include simulated mobile-device latency/energy estimates.
    pub with_sim: bool,
    pub enqueued_at: Instant,
}

/// Simulated execution estimate on one mobile device profile
/// (the paper's evaluation target, attached to real inferences).
#[derive(Debug, Clone)]
pub struct SimEstimate {
    pub device: &'static str,
    pub latency_ms: f64,
    pub energy_j: f64,
}

/// The response for one request.
#[derive(Debug, Clone)]
pub struct InferResponse {
    pub id: u64,
    /// Argmax class.
    pub top1: usize,
    /// Top-5 (class, probability).
    pub top5: Vec<(usize, f32)>,
    /// End-to-end latency inside the coordinator.
    pub latency: Duration,
    /// Time spent queued before the batch formed.
    pub queue_time: Duration,
    /// Size of the batch this request rode in.
    pub batch_size: usize,
    pub precision: Precision,
    /// Present when the request asked for simulation.
    pub sim: Vec<SimEstimate>,
}

impl InferResponse {
    /// Wire representation (JSON object) for the TCP server.
    pub fn to_json(&self) -> Json {
        Json::object(vec![
            ("id", Json::num(self.id as f64)),
            ("top1", Json::num(self.top1 as f64)),
            (
                "top5",
                Json::Array(
                    self.top5
                        .iter()
                        .map(|(c, p)| {
                            Json::object(vec![
                                ("class", Json::num(*c as f64)),
                                ("prob", Json::num(*p as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("latency_ms", Json::num(self.latency.as_secs_f64() * 1e3)),
            ("queue_ms", Json::num(self.queue_time.as_secs_f64() * 1e3)),
            ("batch_size", Json::num(self.batch_size as f64)),
            ("precision", Json::str(self.precision.label())),
            (
                "sim",
                Json::Array(
                    self.sim
                        .iter()
                        .map(|s| {
                            Json::object(vec![
                                ("device", Json::str(s.device)),
                                ("latency_ms", Json::num(s.latency_ms)),
                                ("energy_j", Json::num(s.energy_j)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn response_serializes() {
        let r = InferResponse {
            id: 3,
            top1: 7,
            top5: vec![(7, 0.9), (1, 0.05)],
            latency: Duration::from_millis(12),
            queue_time: Duration::from_millis(2),
            batch_size: 4,
            precision: Precision::Precise,
            sim: vec![SimEstimate { device: "Nexus 5", latency_ms: 141.0, energy_j: 0.1 }],
        };
        let j = r.to_json();
        assert_eq!(j.get("top1").unwrap().as_usize(), Some(7));
        assert_eq!(j.get("batch_size").unwrap().as_usize(), Some(4));
        let text = j.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.get("precision").unwrap().as_str(), Some("precise"));
    }
}
