//! Admission control / backpressure — protects the runtime from
//! unbounded queue growth under open-loop overload.
//!
//! Policy: a token-bucket bound on in-flight requests plus a hard queue
//! cap; requests beyond the cap are shed immediately with a retriable
//! error rather than queued into a latency collapse (standard serving
//! practice; the mechanism the paper's phone-local setting never needed
//! but any deployed coordinator does).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Shared admission state.
#[derive(Debug)]
pub struct AdmissionControl {
    max_in_flight: usize,
    in_flight: AtomicUsize,
    admitted: AtomicUsize,
    shed: AtomicUsize,
}

/// RAII permit; releasing decrements the in-flight count.
pub struct Permit {
    ctrl: Arc<AdmissionControl>,
}

impl Drop for Permit {
    fn drop(&mut self) {
        self.ctrl.in_flight.fetch_sub(1, Ordering::AcqRel);
    }
}

impl AdmissionControl {
    pub fn new(max_in_flight: usize) -> Arc<Self> {
        assert!(max_in_flight > 0);
        Arc::new(Self {
            max_in_flight,
            in_flight: AtomicUsize::new(0),
            admitted: AtomicUsize::new(0),
            shed: AtomicUsize::new(0),
        })
    }

    /// Try to admit one request; `None` means shed (caller should
    /// return an overload error to the client).
    pub fn try_admit(self: &Arc<Self>) -> Option<Permit> {
        let mut current = self.in_flight.load(Ordering::Acquire);
        loop {
            if current >= self.max_in_flight {
                self.shed.fetch_add(1, Ordering::Relaxed);
                return None;
            }
            match self.in_flight.compare_exchange_weak(
                current,
                current + 1,
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => {
                    self.admitted.fetch_add(1, Ordering::Relaxed);
                    return Some(Permit { ctrl: self.clone() });
                }
                Err(actual) => current = actual,
            }
        }
    }

    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::Acquire)
    }

    pub fn admitted(&self) -> usize {
        self.admitted.load(Ordering::Relaxed)
    }

    pub fn shed(&self) -> usize {
        self.shed.load(Ordering::Relaxed)
    }

    /// Shed fraction over the lifetime of the controller.
    pub fn shed_rate(&self) -> f64 {
        let total = self.admitted() + self.shed();
        if total == 0 {
            0.0
        } else {
            self.shed() as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admits_up_to_cap_then_sheds() {
        let ctrl = AdmissionControl::new(3);
        let p1 = ctrl.try_admit().unwrap();
        let _p2 = ctrl.try_admit().unwrap();
        let _p3 = ctrl.try_admit().unwrap();
        assert!(ctrl.try_admit().is_none());
        assert_eq!(ctrl.in_flight(), 3);
        assert_eq!(ctrl.shed(), 1);
        drop(p1);
        assert_eq!(ctrl.in_flight(), 2);
        let _p4 = ctrl.try_admit().unwrap();
        assert_eq!(ctrl.admitted(), 4);
    }

    #[test]
    fn shed_rate_accounts_both() {
        let ctrl = AdmissionControl::new(1);
        let _p = ctrl.try_admit().unwrap();
        for _ in 0..3 {
            assert!(ctrl.try_admit().is_none());
        }
        assert!((ctrl.shed_rate() - 0.75).abs() < 1e-9);
    }

    #[test]
    fn concurrent_admission_never_exceeds_cap() {
        let ctrl = AdmissionControl::new(8);
        let peak = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|s| {
            for _ in 0..16 {
                let ctrl = ctrl.clone();
                let peak = peak.clone();
                s.spawn(move || {
                    for _ in 0..500 {
                        if let Some(_permit) = ctrl.try_admit() {
                            let now = ctrl.in_flight();
                            peak.fetch_max(now, Ordering::Relaxed);
                            std::hint::spin_loop();
                        }
                    }
                });
            }
        });
        assert!(peak.load(Ordering::Relaxed) <= 8);
        assert_eq!(ctrl.in_flight(), 0);
    }
}
