//! Admission control / backpressure — protects the runtime from
//! unbounded queue growth under open-loop overload.
//!
//! Two front doors live here:
//!
//! - [`AdmissionControl`] guards the single-device coordinator path: a
//!   token-bucket bound on in-flight requests; requests beyond the cap
//!   are shed immediately with a retriable error rather than queued
//!   into a latency collapse (standard serving practice; the mechanism
//!   the paper's phone-local setting never needed but any deployed
//!   coordinator does).
//! - [`FleetGate`] guards the fleet dispatch path: a fleet-wide queue
//!   cap (resized by the autoscaler as replicas come and go) plus a
//!   saturation flag the autoscaler sets when the fleet cannot absorb
//!   more load (deep SLO breach, exhausted fleet budget, or no replica
//!   accepting traffic) — so the front door sheds *before* enqueueing
//!   instead of letting queues collapse the latency SLO.
//!
//! Shedding at the queue cap is **priority-aware**: when the fleet can
//! name a queued rider cheaper to drop than the arrival (lower
//! priority, then most deadline slack), the gate admits the arrival
//! and the fleet evicts that rider instead of shedding newest-first
//! ([`GateDecision::AdmitEvict`]).  Victim candidates are read
//! straight off each replica's queue
//! ([`Replica::cheapest_evictable`](crate::fleet::Replica::cheapest_evictable)
//! — the replicas are the source of truth; there is no parallel
//! registry of queued riders to keep in sync).  Saturation still
//! sheds every class: the controller closed the door because the
//! fleet as a whole cannot absorb more work, and queue-jumping would
//! only deepen the collapse.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use crate::telemetry::metrics::Counter;

/// Shared admission state.
#[derive(Debug)]
pub struct AdmissionControl {
    max_in_flight: usize,
    in_flight: AtomicUsize,
    admitted: AtomicUsize,
    shed: AtomicUsize,
}

/// RAII permit; releasing decrements the in-flight count.
pub struct Permit {
    ctrl: Arc<AdmissionControl>,
}

impl Drop for Permit {
    fn drop(&mut self) {
        self.ctrl.in_flight.fetch_sub(1, Ordering::AcqRel);
    }
}

impl AdmissionControl {
    pub fn new(max_in_flight: usize) -> Arc<Self> {
        assert!(max_in_flight > 0);
        Arc::new(Self {
            max_in_flight,
            in_flight: AtomicUsize::new(0),
            admitted: AtomicUsize::new(0),
            shed: AtomicUsize::new(0),
        })
    }

    /// Try to admit one request; `None` means shed (caller should
    /// return an overload error to the client).
    pub fn try_admit(self: &Arc<Self>) -> Option<Permit> {
        let mut current = self.in_flight.load(Ordering::Acquire);
        loop {
            if current >= self.max_in_flight {
                self.shed.fetch_add(1, Ordering::Relaxed);
                return None;
            }
            match self.in_flight.compare_exchange_weak(
                current,
                current + 1,
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => {
                    self.admitted.fetch_add(1, Ordering::Relaxed);
                    return Some(Permit { ctrl: self.clone() });
                }
                Err(actual) => current = actual,
            }
        }
    }

    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::Acquire)
    }

    pub fn admitted(&self) -> usize {
        self.admitted.load(Ordering::Relaxed)
    }

    pub fn shed(&self) -> usize {
        self.shed.load(Ordering::Relaxed)
    }

    /// Shed fraction over the lifetime of the controller.
    pub fn shed_rate(&self) -> f64 {
        let total = self.admitted() + self.shed();
        if total == 0 {
            0.0
        } else {
            self.shed() as f64 / total as f64
        }
    }
}

/// Why the fleet front door refused (or conditionally admitted) a
/// request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GateDecision {
    /// Proceed to placement.
    Admit,
    /// The queue cap is full, but a cheaper-to-drop queued rider
    /// exists: admit this request and evict that rider (the caller
    /// performs the eviction and accounts it as shed).
    AdmitEvict,
    /// The autoscaler reported saturation; shed before enqueueing.
    ShedSaturated,
    /// The fleet-wide queue cap is full and nothing queued is cheaper
    /// to drop; shed before enqueueing.
    ShedQueue,
}

/// Registry counters mirroring the gate's internal tallies — wired by
/// the fleet so `{"cmd":"metrics"}` exposes the front-door decisions
/// as `gate_*_total` series.  Optional: a bare `FleetGate::new` (unit
/// tests, standalone use) carries none and pays nothing.
#[derive(Debug)]
pub struct GateMetrics {
    pub admitted: Arc<Counter>,
    pub shed_saturated: Arc<Counter>,
    pub shed_queue: Arc<Counter>,
    pub evicted: Arc<Counter>,
}

/// Front-door admission for the fleet dispatch path.  Lives inside the
/// fleet's state lock (dispatch is already serialized there), so plain
/// fields suffice; the autoscaler resizes the cap and flips the
/// saturation flag each control tick.
#[derive(Debug)]
pub struct FleetGate {
    /// Cap on riders queued or running across the whole fleet
    /// (`active replicas x queue_per_replica`).
    max_queue: usize,
    /// Saturation reported by the autoscaler control loop.
    saturated: bool,
    admitted: u64,
    shed_saturated: u64,
    shed_queue: u64,
    /// Queued riders dropped to admit a more urgent arrival.
    evicted: u64,
    /// Mirrored registry counters (see [`GateMetrics`]).
    metrics: Option<GateMetrics>,
}

impl FleetGate {
    pub fn new(max_queue: usize) -> FleetGate {
        assert!(max_queue > 0, "fleet gate needs at least one queue slot");
        FleetGate {
            max_queue,
            saturated: false,
            admitted: 0,
            shed_saturated: 0,
            shed_queue: 0,
            evicted: 0,
            metrics: None,
        }
    }

    /// Mirror every gate decision into registry counters.
    pub fn set_metrics(&mut self, metrics: GateMetrics) {
        self.metrics = Some(metrics);
    }

    /// Decide admission given the fleet's current total queue depth
    /// and whether the fleet found a queued rider cheaper to drop than
    /// this arrival (`can_evict`) — priority shedding: under queue
    /// pressure the cheapest rider goes, not the newest.
    pub fn admit(&mut self, queued: usize, can_evict: bool) -> GateDecision {
        let decision = if self.saturated {
            self.shed_saturated += 1;
            GateDecision::ShedSaturated
        } else if queued >= self.max_queue {
            if can_evict {
                self.admitted += 1;
                self.evicted += 1;
                GateDecision::AdmitEvict
            } else {
                self.shed_queue += 1;
                GateDecision::ShedQueue
            }
        } else {
            self.admitted += 1;
            GateDecision::Admit
        };
        if let Some(m) = &self.metrics {
            match decision {
                GateDecision::Admit => m.admitted.inc(),
                GateDecision::AdmitEvict => {
                    m.admitted.inc();
                    m.evicted.inc();
                }
                GateDecision::ShedSaturated => m.shed_saturated.inc(),
                GateDecision::ShedQueue => m.shed_queue.inc(),
            }
        }
        decision
    }

    /// Resize the queue cap as the autoscaler adds or drains replicas.
    pub fn resize(&mut self, max_queue: usize) {
        self.max_queue = max_queue.max(1);
    }

    pub fn set_saturated(&mut self, saturated: bool) {
        self.saturated = saturated;
    }

    pub fn is_saturated(&self) -> bool {
        self.saturated
    }

    pub fn max_queue(&self) -> usize {
        self.max_queue
    }

    pub fn admitted(&self) -> u64 {
        self.admitted
    }

    pub fn shed_saturated(&self) -> u64 {
        self.shed_saturated
    }

    pub fn shed_queue(&self) -> u64 {
        self.shed_queue
    }

    pub fn evicted(&self) -> u64 {
        self.evicted
    }

    /// Counter snapshot for the autoscaler report (`autoscale_stats`).
    pub fn stats(&self) -> GateStats {
        GateStats {
            max_queue: self.max_queue,
            saturated: self.saturated,
            admitted: self.admitted,
            shed_saturated: self.shed_saturated,
            shed_queue: self.shed_queue,
            evicted: self.evicted,
        }
    }
}

/// Point-in-time [`FleetGate`] counters.  `admitted` counts gate-level
/// admissions (a request the gate passed can still shed at placement
/// if no replica accepts traffic), the two shed counters split the
/// fleet's front-door sheds by cause, and `evicted` counts queued
/// riders dropped in favor of a more urgent arrival.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GateStats {
    pub max_queue: usize,
    pub saturated: bool,
    pub admitted: u64,
    pub shed_saturated: u64,
    pub shed_queue: u64,
    pub evicted: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fleet_gate_sheds_on_queue_cap() {
        let mut g = FleetGate::new(2);
        assert_eq!(g.admit(0, false), GateDecision::Admit);
        assert_eq!(g.admit(1, false), GateDecision::Admit);
        assert_eq!(g.admit(2, false), GateDecision::ShedQueue);
        assert_eq!(g.admitted(), 2);
        assert_eq!(g.shed_queue(), 1);
        // the autoscaler added a replica: more room
        g.resize(4);
        assert_eq!(g.admit(2, false), GateDecision::Admit);
    }

    #[test]
    fn fleet_gate_evicts_instead_of_shedding_newest_first() {
        let mut g = FleetGate::new(2);
        assert_eq!(g.admit(0, false), GateDecision::Admit);
        assert_eq!(g.admit(1, false), GateDecision::Admit);
        // a cheaper queued rider exists: the arrival is admitted and
        // the victim goes instead
        assert_eq!(g.admit(2, true), GateDecision::AdmitEvict);
        assert_eq!(g.admitted(), 3);
        assert_eq!(g.evicted(), 1);
        assert_eq!(g.shed_queue(), 0);
        // below the cap, the evictability hint is irrelevant
        assert_eq!(g.admit(1, true), GateDecision::Admit);
        assert_eq!(g.evicted(), 1);
        assert_eq!(g.stats().evicted, 1);
    }

    #[test]
    fn fleet_gate_saturation_overrides_queue_room() {
        let mut g = FleetGate::new(8);
        g.set_saturated(true);
        assert!(g.is_saturated());
        assert_eq!(g.admit(0, false), GateDecision::ShedSaturated);
        // saturation sheds every class — even with an evictable victim
        assert_eq!(g.admit(0, true), GateDecision::ShedSaturated);
        assert_eq!(g.shed_saturated(), 2);
        g.set_saturated(false);
        assert_eq!(g.admit(0, false), GateDecision::Admit);
    }

    #[test]
    fn fleet_gate_resize_never_closes_entirely() {
        let mut g = FleetGate::new(4);
        g.resize(0); // a fleet scaled to min keeps one slot open
        assert_eq!(g.max_queue(), 1);
        assert_eq!(g.admit(0, false), GateDecision::Admit);
    }

    #[test]
    fn admits_up_to_cap_then_sheds() {
        let ctrl = AdmissionControl::new(3);
        let p1 = ctrl.try_admit().unwrap();
        let _p2 = ctrl.try_admit().unwrap();
        let _p3 = ctrl.try_admit().unwrap();
        assert!(ctrl.try_admit().is_none());
        assert_eq!(ctrl.in_flight(), 3);
        assert_eq!(ctrl.shed(), 1);
        drop(p1);
        assert_eq!(ctrl.in_flight(), 2);
        let _p4 = ctrl.try_admit().unwrap();
        assert_eq!(ctrl.admitted(), 4);
    }

    #[test]
    fn shed_rate_accounts_both() {
        let ctrl = AdmissionControl::new(1);
        let _p = ctrl.try_admit().unwrap();
        for _ in 0..3 {
            assert!(ctrl.try_admit().is_none());
        }
        assert!((ctrl.shed_rate() - 0.75).abs() < 1e-9);
    }

    #[test]
    fn concurrent_admission_never_exceeds_cap() {
        let ctrl = AdmissionControl::new(8);
        let peak = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|s| {
            for _ in 0..16 {
                let ctrl = ctrl.clone();
                let peak = peak.clone();
                s.spawn(move || {
                    for _ in 0..500 {
                        if let Some(_permit) = ctrl.try_admit() {
                            let now = ctrl.in_flight();
                            peak.fetch_max(now, Ordering::Relaxed);
                            std::hint::spin_loop();
                        }
                    }
                });
            }
        });
        assert!(peak.load(Ordering::Relaxed) <= 8);
        assert_eq!(ctrl.in_flight(), 0);
    }
}
