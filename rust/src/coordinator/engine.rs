//! The coordinator core: request routing, dynamic batching, and the
//! runtime thread that owns the PJRT executables.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::convnet::ops;
use crate::model::graph::{ConvSpec, SqueezeNet};
use crate::runtime::RuntimeEngine;
use crate::simulator::autotune::autotune_network;
use crate::simulator::cost::{network_time, RunMode};
use crate::simulator::device::{DeviceProfile, Precision};
use crate::simulator::power::energy_joules;
use crate::telemetry::Telemetry;

use super::batcher::{plan_batches, BatcherConfig};
use super::request::{InferRequest, InferResponse, Qos, SimEstimate};

/// Coordinator construction parameters.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub artifacts_dir: PathBuf,
    /// Precisions to serve (each gets its own executor set and queue).
    pub precisions: Vec<Precision>,
    /// Batch sizes to compile per precision (must include 1).
    pub batches: Vec<usize>,
    pub batcher: BatcherConfig,
}

impl CoordinatorConfig {
    pub fn new(artifacts_dir: PathBuf) -> Self {
        Self {
            artifacts_dir,
            precisions: vec![Precision::Precise, Precision::Imprecise],
            batches: vec![1, 2, 4, 8],
            batcher: BatcherConfig::default(),
        }
    }
}

type Reply = Sender<Result<InferResponse, String>>;

enum Envelope {
    Request(Box<InferRequest>, Reply),
    Shutdown,
}

struct BatchJob {
    precision: Precision,
    items: Vec<(Box<InferRequest>, Reply)>,
    formed_at: Instant,
}

enum RuntimeMsg {
    Job(BatchJob),
    Shutdown,
}

/// The running coordinator (router + batcher + runtime threads).
pub struct Coordinator {
    tx: Sender<Envelope>,
    next_id: AtomicU64,
    pub telemetry: Arc<Telemetry>,
    batcher_handle: Option<JoinHandle<()>>,
    runtime_handle: Option<JoinHandle<()>>,
    image_len: usize,
}

impl Coordinator {
    /// Start the coordinator: spawns the runtime thread (which compiles
    /// all executables) and the batcher thread. Blocks until the
    /// runtime is ready or failed.
    pub fn start(config: CoordinatorConfig) -> Result<Coordinator> {
        assert!(config.batches.contains(&1), "batch size 1 is required");
        let telemetry = Arc::new(Telemetry::default());

        // runtime thread: owns the (non-Send) PJRT state
        let (job_tx, job_rx) = mpsc::channel::<RuntimeMsg>();
        let (ready_tx, ready_rx) = mpsc::channel::<std::result::Result<usize, String>>();
        let rt_cfg = config.clone();
        let rt_telemetry = telemetry.clone();
        let runtime_handle = std::thread::Builder::new()
            .name("mcn-runtime".into())
            .spawn(move || runtime_thread(rt_cfg, job_rx, ready_tx, rt_telemetry))
            .context("spawning runtime thread")?;
        let image_len = ready_rx
            .recv()
            .context("runtime thread died before signalling readiness")?
            .map_err(|e| anyhow::anyhow!("runtime startup failed: {e}"))?;

        // batcher thread: pure queue logic
        let (tx, rx) = mpsc::channel::<Envelope>();
        let b_cfg = config.clone();
        let b_telemetry = telemetry.clone();
        let batcher_handle = std::thread::Builder::new()
            .name("mcn-batcher".into())
            .spawn(move || batcher_thread(b_cfg, rx, job_tx, b_telemetry))
            .context("spawning batcher thread")?;

        Ok(Coordinator {
            tx,
            next_id: AtomicU64::new(1),
            telemetry,
            batcher_handle: Some(batcher_handle),
            runtime_handle: Some(runtime_handle),
            image_len,
        })
    }

    /// Expected image length (H*W*3).
    pub fn image_len(&self) -> usize {
        self.image_len
    }

    /// Submit a default-class request and obtain a receiver for the
    /// response.
    pub fn submit(
        &self,
        image: Vec<f32>,
        precision: Precision,
        with_sim: bool,
    ) -> Result<Receiver<Result<InferResponse, String>>> {
        self.submit_qos(image, precision, with_sim, Qos::default())
    }

    /// [`submit`](Self::submit) with an explicit QoS class.  The
    /// single-device path records the class on the request (QoS is
    /// *enforced* on the fleet path; see
    /// [`Fleet::dispatch`](crate::fleet::Fleet::dispatch)).
    pub fn submit_qos(
        &self,
        image: Vec<f32>,
        precision: Precision,
        with_sim: bool,
        qos: Qos,
    ) -> Result<Receiver<Result<InferResponse, String>>> {
        if image.len() != self.image_len {
            anyhow::bail!("image must have {} values, got {}", self.image_len, image.len());
        }
        qos.validate().map_err(|e| anyhow::anyhow!(e))?;
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let req = InferRequest { id, image, precision, with_sim, qos, enqueued_at: Instant::now() };
        let (reply_tx, reply_rx) = mpsc::channel();
        self.telemetry.counters.requests.fetch_add(1, Ordering::Relaxed);
        self.tx
            .send(Envelope::Request(Box::new(req), reply_tx))
            .map_err(|_| anyhow::anyhow!("coordinator is shut down"))?;
        Ok(reply_rx)
    }

    /// Blocking inference (default QoS class).
    pub fn infer(
        &self,
        image: Vec<f32>,
        precision: Precision,
        with_sim: bool,
    ) -> Result<InferResponse> {
        self.infer_qos(image, precision, with_sim, Qos::default())
    }

    /// Blocking inference with an explicit QoS class.
    pub fn infer_qos(
        &self,
        image: Vec<f32>,
        precision: Precision,
        with_sim: bool,
        qos: Qos,
    ) -> Result<InferResponse> {
        let rx = self.submit_qos(image, precision, with_sim, qos)?;
        rx.recv()
            .context("coordinator dropped the request")?
            .map_err(|e| anyhow::anyhow!(e))
    }

    /// Graceful shutdown (drains in-flight work).
    pub fn shutdown(&mut self) {
        let _ = self.tx.send(Envelope::Shutdown);
        if let Some(h) = self.batcher_handle.take() {
            let _ = h.join();
        }
        if let Some(h) = self.runtime_handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Batcher thread: group per precision, flush on size or deadline.
fn batcher_thread(
    config: CoordinatorConfig,
    rx: Receiver<Envelope>,
    job_tx: Sender<RuntimeMsg>,
    telemetry: Arc<Telemetry>,
) {
    let mut queues: HashMap<Precision, Vec<(Box<InferRequest>, Reply)>> = HashMap::new();
    let tick = config.batcher.max_wait.min(Duration::from_millis(1)).max(Duration::from_micros(200));
    'outer: loop {
        // Drain the channel (blocking briefly so we don't spin).
        match rx.recv_timeout(tick) {
            Ok(Envelope::Request(req, reply)) => {
                queues.entry(req.precision).or_default().push((req, reply));
                // Opportunistically drain whatever else is queued.
                while let Ok(env) = rx.try_recv() {
                    match env {
                        Envelope::Request(req, reply) => {
                            queues.entry(req.precision).or_default().push((req, reply));
                        }
                        Envelope::Shutdown => {
                            flush_all(&mut queues, &config, &job_tx, &telemetry, true);
                            let _ = job_tx.send(RuntimeMsg::Shutdown);
                            break 'outer;
                        }
                    }
                }
            }
            Ok(Envelope::Shutdown) => {
                flush_all(&mut queues, &config, &job_tx, &telemetry, true);
                let _ = job_tx.send(RuntimeMsg::Shutdown);
                break;
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                flush_all(&mut queues, &config, &job_tx, &telemetry, true);
                let _ = job_tx.send(RuntimeMsg::Shutdown);
                break;
            }
        }
        flush_all(&mut queues, &config, &job_tx, &telemetry, false);
    }
}

/// Flush queues per policy; `force` flushes everything (shutdown).
fn flush_all(
    queues: &mut HashMap<Precision, Vec<(Box<InferRequest>, Reply)>>,
    config: &CoordinatorConfig,
    job_tx: &Sender<RuntimeMsg>,
    telemetry: &Telemetry,
    force: bool,
) {
    for (&precision, queue) in queues.iter_mut() {
        if queue.is_empty() {
            continue;
        }
        let oldest_age = queue[0].0.enqueued_at.elapsed();
        let should_flush =
            force || queue.len() >= config.batcher.max_batch || oldest_age >= config.batcher.max_wait;
        if !should_flush {
            continue;
        }
        let items: Vec<_> = queue.drain(..).collect();
        let mut remaining = items;
        for size in plan_batches(remaining.len(), &config.batches) {
            let rest = remaining.split_off(size);
            let batch = std::mem::replace(&mut remaining, rest);
            telemetry.counters.batches.fetch_add(1, Ordering::Relaxed);
            telemetry
                .counters
                .batched_requests
                .fetch_add(batch.len() as u64, Ordering::Relaxed);
            let _ = job_tx.send(RuntimeMsg::Job(BatchJob {
                precision,
                items: batch,
                formed_at: Instant::now(),
            }));
        }
    }
}

/// Runtime thread body: compile executables, then serve batch jobs.
fn runtime_thread(
    config: CoordinatorConfig,
    rx: Receiver<RuntimeMsg>,
    ready_tx: Sender<std::result::Result<usize, String>>,
    telemetry: Arc<Telemetry>,
) {
    let engine = match RuntimeEngine::load(&config.artifacts_dir, &config.precisions, &config.batches)
    {
        Ok(e) => e,
        Err(err) => {
            let _ = ready_tx.send(Err(format!("{err:#}")));
            return;
        }
    };
    let image_len =
        engine.manifest.input_hw * engine.manifest.input_hw * crate::model::graph::INPUT_CHANNELS;

    // Precompute the simulated mobile-device estimates attached to
    // responses (per precision; single-image inference).
    let sim_table = build_sim_table();

    let _ = ready_tx.send(Ok(image_len));

    while let Ok(msg) = rx.recv() {
        let job = match msg {
            RuntimeMsg::Job(j) => j,
            RuntimeMsg::Shutdown => break,
        };
        serve_job(&engine, job, &telemetry, &sim_table);
    }
}

fn build_sim_table() -> HashMap<Precision, Vec<SimEstimate>> {
    let net = SqueezeNet::v1_0();
    let mut out: HashMap<Precision, Vec<SimEstimate>> = HashMap::new();
    for precision in Precision::all() {
        let mut v = Vec::new();
        for device in DeviceProfile::all() {
            let plan = autotune_network(&net, precision, &device);
            let g = |spec: &ConvSpec| plan.optimal_g(&spec.name);
            let mode = RunMode::Parallel(precision);
            let latency_ms = network_time(&net, mode, &device, &g);
            let energy_j = energy_joules(&device, mode, latency_ms);
            v.push(SimEstimate { device: device.name, latency_ms, energy_j });
        }
        out.insert(precision, v);
    }
    out
}

fn serve_job(
    engine: &RuntimeEngine,
    job: BatchJob,
    telemetry: &Telemetry,
    sim_table: &HashMap<Precision, Vec<SimEstimate>>,
) {
    let batch = job.items.len();
    let exe = match engine.executor(job.precision, batch) {
        Some(e) => e,
        None => {
            for (_, reply) in job.items {
                telemetry.counters.errors.fetch_add(1, Ordering::Relaxed);
                let _ = reply.send(Err(format!(
                    "no executor for precision={} batch={batch}",
                    job.precision.label()
                )));
            }
            return;
        }
    };
    let mut input = Vec::with_capacity(batch * exe.image_len());
    for (req, _) in &job.items {
        input.extend_from_slice(&req.image);
    }
    let t0 = Instant::now();
    let result = exe.infer(&input);
    telemetry.execute_time.record(t0.elapsed());

    match result {
        Ok(all_logits) => {
            for ((req, reply), logits) in job.items.into_iter().zip(all_logits) {
                let probs = ops::softmax(&logits);
                let top5 = ops::top_k(&probs, 5);
                let latency = req.enqueued_at.elapsed();
                let queue_time = job.formed_at.duration_since(req.enqueued_at);
                telemetry.latency.record(latency);
                telemetry.queue_time.record(queue_time);
                telemetry.counters.responses.fetch_add(1, Ordering::Relaxed);
                let sim = if req.with_sim {
                    sim_table.get(&req.precision).cloned().unwrap_or_default()
                } else {
                    Vec::new()
                };
                let _ = reply.send(Ok(InferResponse {
                    id: req.id,
                    top1: ops::argmax(&probs),
                    top5,
                    latency,
                    queue_time,
                    batch_size: batch,
                    precision: req.precision,
                    sim,
                }));
            }
        }
        Err(err) => {
            for (_, reply) in job.items {
                telemetry.counters.errors.fetch_add(1, Ordering::Relaxed);
                let _ = reply.send(Err(format!("{err:#}")));
            }
        }
    }
}
