//! Fleet-wide metrics registry: counters, gauges, and log-bucketed
//! histograms with O(1) record and O(buckets) percentile queries.
//!
//! The registry is the quantitative half of the observability layer
//! (the tracing half lives in [`crate::telemetry::trace`]).  Metrics
//! are named strings, optionally labeled (`name{k="v",...}` via
//! [`labeled`]) by replica, QoS class, and model; the fleet registers
//! its conservation counters (`arrivals`, `completed`, `shed`, `lost`,
//! `expired`, ...) at the same code points that maintain the
//! `FleetReport` totals, so a snapshot always reconciles exactly with
//! the report — that invariant is enforced by the seeded test in
//! `tests/telemetry_e2e.rs`.
//!
//! ## Log-bucketed histograms
//!
//! Latency samples land in geometric buckets with
//! [`BUCKETS_PER_OCTAVE`] buckets per factor-of-two, so recording is a
//! single increment and a percentile query is one pass over the bucket
//! array — no sorting, no sample retention.  The relative width of a
//! bucket is `2^(1/256) - 1 ≈ 0.27%`, and the reported value is the
//! geometric midpoint of the winning bucket, so any percentile is
//! within ~0.14% of the exact sample statistic — far inside every
//! latency tolerance in the repo while removing the
//! clone-and-sort-under-a-mutex cost the previous recorder paid per
//! query.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::json::Json;

/// Geometric buckets per factor-of-two of latency.
pub const BUCKETS_PER_OCTAVE: usize = 256;
/// Lower edge of the first real bucket (everything at or below lands
/// in bucket 0).
pub const MIN_BUCKET_MS: f64 = 1e-3;
/// Total bucket count: bucket 0 (underflow), ~32 octaves of range
/// (1 µs .. ~70 min of virtual time), and a top overflow bucket.
pub const NUM_BUCKETS: usize = 2 + 32 * BUCKETS_PER_OCTAVE;

/// Bucket index for a sample in milliseconds.  NaN and non-positive
/// values land in the underflow bucket.
pub fn bucket_of(ms: f64) -> usize {
    if !(ms > MIN_BUCKET_MS) {
        return 0;
    }
    let idx = ((ms / MIN_BUCKET_MS).log2() * BUCKETS_PER_OCTAVE as f64).floor() as usize + 1;
    idx.min(NUM_BUCKETS - 1)
}

/// Representative value (geometric bucket midpoint) in milliseconds.
pub fn bucket_value_ms(idx: usize) -> f64 {
    if idx == 0 {
        return MIN_BUCKET_MS;
    }
    MIN_BUCKET_MS * 2f64.powf((idx as f64 - 0.5) / BUCKETS_PER_OCTAVE as f64)
}

/// Monotone event counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-value-wins float gauge (f64 bits in an atomic).
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

#[derive(Debug)]
struct HistInner {
    counts: Vec<u32>,
    total: u64,
    sum_ms: f64,
}

/// Cumulative log-bucketed latency histogram (no sliding window; for
/// windowed semantics see
/// [`LatencyRecorder`](crate::telemetry::LatencyRecorder), which
/// shares the bucket layout).
#[derive(Debug)]
pub struct Histogram {
    inner: Mutex<HistInner>,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            inner: Mutex::new(HistInner {
                counts: vec![0; NUM_BUCKETS],
                total: 0,
                sum_ms: 0.0,
            }),
        }
    }

    /// O(1): one bucket increment.
    pub fn record_ms(&self, ms: f64) {
        let mut h = self.inner.lock().unwrap();
        h.counts[bucket_of(ms)] += 1;
        h.total += 1;
        h.sum_ms += ms;
    }

    pub fn count(&self) -> u64 {
        self.inner.lock().unwrap().total
    }

    pub fn mean_ms(&self) -> Option<f64> {
        let h = self.inner.lock().unwrap();
        if h.total == 0 {
            return None;
        }
        Some(h.sum_ms / h.total as f64)
    }

    /// Percentile in milliseconds (p in [0,1], clamped); `None` when
    /// empty.  O(buckets): one cumulative walk.
    pub fn percentile_ms(&self, p: f64) -> Option<f64> {
        let h = self.inner.lock().unwrap();
        if h.total == 0 {
            return None;
        }
        let rank = ((h.total - 1) as f64 * p.clamp(0.0, 1.0)).round() as u64;
        let mut seen = 0u64;
        for (idx, &c) in h.counts.iter().enumerate() {
            seen += c as u64;
            if seen > rank {
                return Some(bucket_value_ms(idx));
            }
        }
        Some(bucket_value_ms(NUM_BUCKETS - 1))
    }
}

/// Render a metric name with labels: `name{k="v",...}`.  Labels are
/// part of the registry key, so the same base name with different
/// labels is a distinct time series.
pub fn labeled(name: &str, labels: &[(&str, &str)]) -> String {
    if labels.is_empty() {
        return name.to_string();
    }
    let body: Vec<String> =
        labels.iter().map(|(k, v)| format!("{k}=\"{v}\"")).collect();
    format!("{name}{{{}}}", body.join(","))
}

/// Named-metric registry.  `counter`/`gauge`/`histogram` return shared
/// handles (get-or-register), so hot paths resolve a metric once and
/// update it lock-free afterwards; `snapshot` serializes everything in
/// deterministic (sorted) order.
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut m = self.counters.lock().unwrap();
        m.entry(name.to_string()).or_default().clone()
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut m = self.gauges.lock().unwrap();
        m.entry(name.to_string()).or_default().clone()
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut m = self.histograms.lock().unwrap();
        m.entry(name.to_string()).or_insert_with(|| Arc::new(Histogram::new())).clone()
    }

    /// Current value of a counter, `None` if never registered.
    pub fn counter_value(&self, name: &str) -> Option<u64> {
        self.counters.lock().unwrap().get(name).map(|c| c.get())
    }

    /// Current value of a gauge, `None` if never registered.
    pub fn gauge_value(&self, name: &str) -> Option<f64> {
        self.gauges.lock().unwrap().get(name).map(|g| g.get())
    }

    /// Sum of every counter whose name starts with `prefix` — used to
    /// roll labeled series (`completed{replica=...}`) up to a total.
    pub fn counter_sum(&self, prefix: &str) -> u64 {
        self.counters
            .lock()
            .unwrap()
            .iter()
            .filter(|(k, _)| k.starts_with(prefix))
            .map(|(_, c)| c.get())
            .sum()
    }

    /// Full registry snapshot as JSON (counters, gauges, histogram
    /// summaries), keys sorted for deterministic output.
    pub fn snapshot(&self) -> Json {
        let counters: Vec<(String, Json)> = self
            .counters
            .lock()
            .unwrap()
            .iter()
            .map(|(k, c)| (k.clone(), Json::num(c.get() as f64)))
            .collect();
        let gauges: Vec<(String, Json)> = self
            .gauges
            .lock()
            .unwrap()
            .iter()
            .map(|(k, g)| (k.clone(), Json::num(g.get())))
            .collect();
        let opt_num = |v: Option<f64>| v.map(Json::num).unwrap_or(Json::Null);
        let histograms: Vec<(String, Json)> = self
            .histograms
            .lock()
            .unwrap()
            .iter()
            .map(|(k, h)| {
                (
                    k.clone(),
                    Json::object(vec![
                        ("count", Json::num(h.count() as f64)),
                        ("mean_ms", opt_num(h.mean_ms())),
                        ("p50_ms", opt_num(h.percentile_ms(0.50))),
                        ("p95_ms", opt_num(h.percentile_ms(0.95))),
                        ("p99_ms", opt_num(h.percentile_ms(0.99))),
                    ]),
                )
            })
            .collect();
        Json::Object(vec![
            ("counters".to_string(), Json::Object(counters)),
            ("gauges".to_string(), Json::Object(gauges)),
            ("histograms".to_string(), Json::Object(histograms)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_are_monotone_and_tight() {
        // Every sample's representative value is within 0.2% of the
        // sample itself (the histogram's whole accuracy story).
        for &ms in &[0.002, 0.5, 1.0, 7.3, 55.8, 812.0, 12_345.6] {
            let idx = bucket_of(ms);
            let rep = bucket_value_ms(idx);
            assert!(
                (rep - ms).abs() / ms < 2e-3,
                "rep {rep} too far from sample {ms}"
            );
        }
        // Monotone: bigger samples never land in earlier buckets.
        assert!(bucket_of(1.0) < bucket_of(2.0));
        assert!(bucket_of(2.0) < bucket_of(1000.0));
        // Degenerate inputs stay in range.
        assert_eq!(bucket_of(f64::NAN), 0);
        assert_eq!(bucket_of(-5.0), 0);
        assert_eq!(bucket_of(f64::INFINITY), NUM_BUCKETS - 1);
    }

    #[test]
    fn histogram_percentiles_track_samples() {
        let h = Histogram::new();
        for i in 1..=1000 {
            h.record_ms(i as f64);
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.percentile_ms(0.50).unwrap();
        let p95 = h.percentile_ms(0.95).unwrap();
        let p99 = h.percentile_ms(0.99).unwrap();
        assert!((p50 - 500.0).abs() / 500.0 < 0.01, "p50 {p50}");
        assert!((p95 - 950.0).abs() / 950.0 < 0.01, "p95 {p95}");
        assert!((p99 - 990.0).abs() / 990.0 < 0.01, "p99 {p99}");
        assert!(p50 < p95 && p95 < p99);
        assert!((h.mean_ms().unwrap() - 500.5).abs() < 1e-9);
        assert!(Histogram::new().percentile_ms(0.5).is_none());
    }

    #[test]
    fn registry_handles_are_shared() {
        let reg = MetricsRegistry::new();
        let a = reg.counter("fleet_arrivals_total");
        let b = reg.counter("fleet_arrivals_total");
        a.inc();
        b.add(2);
        assert_eq!(reg.counter_value("fleet_arrivals_total"), Some(3));
        assert_eq!(reg.counter_value("never_registered"), None);
        reg.gauge("fleet_active_replicas").set(4.0);
        assert_eq!(reg.gauge_value("fleet_active_replicas"), Some(4.0));
    }

    #[test]
    fn labeled_series_are_distinct_and_summable() {
        let reg = MetricsRegistry::new();
        reg.counter(&labeled("completed", &[("replica", "r0"), ("class", "hi")])).add(3);
        reg.counter(&labeled("completed", &[("replica", "r1"), ("class", "lo")])).add(4);
        assert_eq!(reg.counter_sum("completed"), 7);
        assert_eq!(
            labeled("x", &[("a", "1")]),
            "x{a=\"1\"}"
        );
        assert_eq!(labeled("x", &[]), "x");
    }

    #[test]
    fn snapshot_is_deterministic_json() {
        let reg = MetricsRegistry::new();
        reg.counter("b_total").inc();
        reg.counter("a_total").inc();
        reg.gauge("g").set(1.5);
        reg.histogram("lat_ms").record_ms(10.0);
        let snap = reg.snapshot();
        let counters = snap.get("counters").unwrap();
        // BTreeMap iteration: sorted keys regardless of insert order.
        match counters {
            Json::Object(pairs) => {
                assert_eq!(pairs[0].0, "a_total");
                assert_eq!(pairs[1].0, "b_total");
            }
            _ => panic!("counters must be an object"),
        }
        let hist = snap.get("histograms").unwrap().get("lat_ms").unwrap();
        assert_eq!(hist.get("count").unwrap().as_f64(), Some(1.0));
        let p50 = hist.get("p50_ms").unwrap().as_f64().unwrap();
        assert!((p50 - 10.0).abs() / 10.0 < 0.01);
    }
}
