//! Serving telemetry: latency histograms, counters, request tracing,
//! a fleet-wide metrics registry, and report rendering (the
//! Trepn-style monitoring hooks of §IV-C, applied to the real serving
//! stack).
//!
//! - [`LatencyRecorder`]: sliding-window percentiles, now backed by
//!   the log-bucketed histogram layout of [`metrics`] — O(1) record,
//!   O(buckets) percentile, no clone-and-sort under the mutex.
//! - [`metrics`]: counters / gauges / histograms behind a
//!   [`MetricsRegistry`](metrics::MetricsRegistry), labeled by
//!   replica, QoS class, and model; snapshotted by `{"cmd":"metrics"}`.
//! - [`trace`]: per-request lifecycle spans in virtual time with a
//!   sampling [`Tracer`](trace::Tracer), exported as Chrome
//!   trace-event JSON via `{"cmd":"trace_dump"}` / `--trace-out`.

pub mod metrics;
pub mod trace;

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use metrics::{bucket_of, bucket_value_ms, NUM_BUCKETS};

#[derive(Debug)]
struct RecorderInner {
    /// Raw samples in arrival order (for eviction + exact mean).
    window: VecDeque<f64>,
    /// Log-bucket counts over the window (see [`metrics::bucket_of`]).
    counts: Vec<u32>,
}

/// Sliding-window latency recorder (keeps the most recent `cap`
/// samples).  Recording is O(1): push into the window ring, bump the
/// sample's log bucket, and decrement the evicted sample's bucket.
/// Percentile queries walk the bucket array (O(buckets), no sort, no
/// clone) and interpolate between bucket midpoints, so results are
/// within the bucket width (~0.3%) of the exact order statistic — the
/// API is unchanged, so `fleet_stats` consumers are untouched.
#[derive(Debug)]
pub struct LatencyRecorder {
    cap: usize,
    inner: Mutex<RecorderInner>,
}

impl LatencyRecorder {
    pub fn new(cap: usize) -> Self {
        Self {
            cap,
            inner: Mutex::new(RecorderInner {
                window: VecDeque::with_capacity(cap.min(4096)),
                counts: vec![0; NUM_BUCKETS],
            }),
        }
    }

    pub fn record(&self, d: Duration) {
        let ms = d.as_secs_f64() * 1e3;
        let mut s = self.inner.lock().unwrap();
        if s.window.len() == self.cap {
            if let Some(old) = s.window.pop_front() {
                let idx = bucket_of(old);
                s.counts[idx] -= 1;
            }
        }
        let idx = bucket_of(ms);
        s.counts[idx] += 1;
        s.window.push_back(ms);
    }

    pub fn count(&self) -> usize {
        self.inner.lock().unwrap().window.len()
    }

    /// Percentile in milliseconds (p in [0,1], clamped); None when
    /// empty.  Interpolates linearly between the bucket midpoints of
    /// the two nearest ranks, so small windows don't snap to a single
    /// bucket.
    pub fn percentile_ms(&self, p: f64) -> Option<f64> {
        let s = self.inner.lock().unwrap();
        let n = s.window.len();
        if n == 0 {
            return None;
        }
        let rank = (n - 1) as f64 * p.clamp(0.0, 1.0);
        let lo = rank.floor() as u64;
        let hi = rank.ceil() as u64;
        let frac = rank - lo as f64;
        // One cumulative walk finds both ranks (hi is lo or lo+1).
        let mut seen = 0u64;
        let mut lo_v = None;
        for (idx, &c) in s.counts.iter().enumerate() {
            seen += c as u64;
            if lo_v.is_none() && seen > lo {
                lo_v = Some(bucket_value_ms(idx));
            }
            if seen > hi {
                let hi_v = bucket_value_ms(idx);
                let lo_v = lo_v.unwrap_or(hi_v);
                return Some(lo_v + (hi_v - lo_v) * frac);
            }
        }
        lo_v
    }

    pub fn mean_ms(&self) -> Option<f64> {
        let s = self.inner.lock().unwrap();
        if s.window.is_empty() {
            return None;
        }
        Some(s.window.iter().sum::<f64>() / s.window.len() as f64)
    }
}

#[cfg(test)]
mod recorder_tests {
    use super::*;

    /// Bucketed percentiles are exact up to the bucket width; assert
    /// within 1% (actual error ≲ 0.3%).
    fn assert_close(got: Option<f64>, want: f64) {
        let got = got.expect("percentile exists");
        assert!(
            (got - want).abs() / want < 0.01,
            "got {got}, want ~{want}"
        );
    }

    #[test]
    fn percentile_interpolates_between_ranks() {
        let r = LatencyRecorder::new(8);
        for ms in [1u64, 2, 3, 4] {
            r.record(Duration::from_millis(ms));
        }
        // rank 1.5 between 2 and 3
        assert_close(r.percentile_ms(0.5), 2.5);
        assert_close(r.percentile_ms(0.0), 1.0);
        assert_close(r.percentile_ms(1.0), 4.0);
        // out-of-range p clamps instead of panicking
        assert_eq!(r.percentile_ms(2.0), r.percentile_ms(1.0));
    }

    #[test]
    fn window_evicts_oldest_first() {
        let r = LatencyRecorder::new(3);
        for ms in 1..=5u64 {
            r.record(Duration::from_millis(ms));
        }
        assert_eq!(r.count(), 3);
        // only 3,4,5 remain
        assert_close(r.percentile_ms(0.0), 3.0);
        assert_close(r.percentile_ms(1.0), 5.0);
    }

    #[test]
    fn bucket_counts_stay_consistent_under_eviction() {
        // Churn far past the cap; the window never over- or
        // under-counts (the eviction decrement hits the right bucket).
        let r = LatencyRecorder::new(16);
        for i in 0..1000u64 {
            r.record(Duration::from_micros(100 + (i * 37) % 5000));
        }
        assert_eq!(r.count(), 16);
        let p0 = r.percentile_ms(0.0).unwrap();
        let p100 = r.percentile_ms(1.0).unwrap();
        assert!(p0 <= p100);
        assert!(p0 > 0.0 && p100 < 6.0);
    }
}

/// Aggregate serving counters.
#[derive(Debug, Default)]
pub struct Counters {
    pub requests: AtomicU64,
    pub responses: AtomicU64,
    pub errors: AtomicU64,
    pub batches: AtomicU64,
    /// Sum of batch sizes (for mean batch size).
    pub batched_requests: AtomicU64,
}

impl Counters {
    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.batched_requests.load(Ordering::Relaxed) as f64 / b as f64
        }
    }
}

/// Shared telemetry bundle for the coordinator.
#[derive(Debug)]
pub struct Telemetry {
    pub latency: LatencyRecorder,
    pub queue_time: LatencyRecorder,
    pub execute_time: LatencyRecorder,
    pub counters: Counters,
}

impl Default for Telemetry {
    fn default() -> Self {
        Self {
            latency: LatencyRecorder::new(4096),
            queue_time: LatencyRecorder::new(4096),
            execute_time: LatencyRecorder::new(4096),
            counters: Counters::default(),
        }
    }
}

impl Telemetry {
    /// Multi-line human-readable report.
    pub fn report(&self) -> String {
        let pct = |r: &LatencyRecorder, p: f64| {
            r.percentile_ms(p).map(|v| format!("{v:.2}")).unwrap_or_else(|| "-".into())
        };
        format!(
            "requests={} responses={} errors={} batches={} mean_batch={:.2}\n\
             latency_ms: mean={} p50={} p95={} p99={}\n\
             queue_ms:   p50={} p95={}\n\
             execute_ms: p50={} p95={}",
            self.counters.requests.load(Ordering::Relaxed),
            self.counters.responses.load(Ordering::Relaxed),
            self.counters.errors.load(Ordering::Relaxed),
            self.counters.batches.load(Ordering::Relaxed),
            self.counters.mean_batch_size(),
            self.latency.mean_ms().map(|v| format!("{v:.2}")).unwrap_or_else(|| "-".into()),
            pct(&self.latency, 0.5),
            pct(&self.latency, 0.95),
            pct(&self.latency, 0.99),
            pct(&self.queue_time, 0.5),
            pct(&self.queue_time, 0.95),
            pct(&self.execute_time, 0.5),
            pct(&self.execute_time, 0.95),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let r = LatencyRecorder::new(100);
        for i in 1..=100 {
            r.record(Duration::from_millis(i));
        }
        let p50 = r.percentile_ms(0.5).unwrap();
        let p95 = r.percentile_ms(0.95).unwrap();
        assert!(p50 < p95);
        assert_eq!(r.count(), 100);
    }

    #[test]
    fn ring_caps_samples() {
        let r = LatencyRecorder::new(10);
        for i in 0..50 {
            r.record(Duration::from_millis(i));
        }
        assert_eq!(r.count(), 10);
        // oldest surviving sample is 40 ms (up to bucket rounding)
        assert!(r.percentile_ms(0.0).unwrap() >= 39.5);
    }

    #[test]
    fn empty_recorder_is_none() {
        let r = LatencyRecorder::new(4);
        assert!(r.percentile_ms(0.5).is_none());
        assert!(r.mean_ms().is_none());
    }

    #[test]
    fn mean_batch_size() {
        let c = Counters::default();
        c.batches.store(2, Ordering::Relaxed);
        c.batched_requests.store(10, Ordering::Relaxed);
        assert_eq!(c.mean_batch_size(), 5.0);
        let report = Telemetry::default().report();
        assert!(report.contains("latency_ms"));
    }
}
