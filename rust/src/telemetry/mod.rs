//! Serving telemetry: latency histograms, counters, and report
//! rendering (the Trepn-style monitoring hooks of §IV-C, applied to the
//! real serving stack).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Sliding-window latency recorder (keeps the most recent `cap`
/// samples).  Backed by a ring (`VecDeque`): evicting the oldest sample
/// is O(1), where a `Vec::remove(0)` would shift the whole window on
/// every record under load.
#[derive(Debug)]
pub struct LatencyRecorder {
    cap: usize,
    samples_ms: Mutex<VecDeque<f64>>,
}

impl LatencyRecorder {
    pub fn new(cap: usize) -> Self {
        Self { cap, samples_ms: Mutex::new(VecDeque::with_capacity(cap.min(4096))) }
    }

    pub fn record(&self, d: Duration) {
        let mut s = self.samples_ms.lock().unwrap();
        if s.len() == self.cap {
            s.pop_front();
        }
        s.push_back(d.as_secs_f64() * 1e3);
    }

    pub fn count(&self) -> usize {
        self.samples_ms.lock().unwrap().len()
    }

    /// Percentile in milliseconds (p in [0,1]); None when empty.
    /// Interpolates linearly between the two nearest ranks, so small
    /// windows don't snap to a single sample.
    pub fn percentile_ms(&self, p: f64) -> Option<f64> {
        let s = self.samples_ms.lock().unwrap();
        if s.is_empty() {
            return None;
        }
        let mut sorted: Vec<f64> = s.iter().copied().collect();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = (sorted.len() - 1) as f64 * p.clamp(0.0, 1.0);
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        let frac = rank - lo as f64;
        Some(sorted[lo] + (sorted[hi] - sorted[lo]) * frac)
    }

    pub fn mean_ms(&self) -> Option<f64> {
        let s = self.samples_ms.lock().unwrap();
        if s.is_empty() {
            return None;
        }
        Some(s.iter().sum::<f64>() / s.len() as f64)
    }
}

#[cfg(test)]
mod recorder_tests {
    use super::*;

    #[test]
    fn percentile_interpolates_between_ranks() {
        let r = LatencyRecorder::new(8);
        for ms in [1u64, 2, 3, 4] {
            r.record(Duration::from_millis(ms));
        }
        // rank 1.5 between 2 and 3
        assert!((r.percentile_ms(0.5).unwrap() - 2.5).abs() < 1e-9);
        assert_eq!(r.percentile_ms(0.0), Some(1.0));
        assert_eq!(r.percentile_ms(1.0), Some(4.0));
        // out-of-range p clamps instead of panicking
        assert_eq!(r.percentile_ms(2.0), Some(4.0));
    }

    #[test]
    fn window_evicts_oldest_first() {
        let r = LatencyRecorder::new(3);
        for ms in 1..=5u64 {
            r.record(Duration::from_millis(ms));
        }
        assert_eq!(r.count(), 3);
        // only 3,4,5 remain
        assert_eq!(r.percentile_ms(0.0), Some(3.0));
        assert_eq!(r.percentile_ms(1.0), Some(5.0));
    }
}

/// Aggregate serving counters.
#[derive(Debug, Default)]
pub struct Counters {
    pub requests: AtomicU64,
    pub responses: AtomicU64,
    pub errors: AtomicU64,
    pub batches: AtomicU64,
    /// Sum of batch sizes (for mean batch size).
    pub batched_requests: AtomicU64,
}

impl Counters {
    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.batched_requests.load(Ordering::Relaxed) as f64 / b as f64
        }
    }
}

/// Shared telemetry bundle for the coordinator.
#[derive(Debug)]
pub struct Telemetry {
    pub latency: LatencyRecorder,
    pub queue_time: LatencyRecorder,
    pub execute_time: LatencyRecorder,
    pub counters: Counters,
}

impl Default for Telemetry {
    fn default() -> Self {
        Self {
            latency: LatencyRecorder::new(4096),
            queue_time: LatencyRecorder::new(4096),
            execute_time: LatencyRecorder::new(4096),
            counters: Counters::default(),
        }
    }
}

impl Telemetry {
    /// Multi-line human-readable report.
    pub fn report(&self) -> String {
        let pct = |r: &LatencyRecorder, p: f64| {
            r.percentile_ms(p).map(|v| format!("{v:.2}")).unwrap_or_else(|| "-".into())
        };
        format!(
            "requests={} responses={} errors={} batches={} mean_batch={:.2}\n\
             latency_ms: mean={} p50={} p95={} p99={}\n\
             queue_ms:   p50={} p95={}\n\
             execute_ms: p50={} p95={}",
            self.counters.requests.load(Ordering::Relaxed),
            self.counters.responses.load(Ordering::Relaxed),
            self.counters.errors.load(Ordering::Relaxed),
            self.counters.batches.load(Ordering::Relaxed),
            self.counters.mean_batch_size(),
            self.latency.mean_ms().map(|v| format!("{v:.2}")).unwrap_or_else(|| "-".into()),
            pct(&self.latency, 0.5),
            pct(&self.latency, 0.95),
            pct(&self.latency, 0.99),
            pct(&self.queue_time, 0.5),
            pct(&self.queue_time, 0.95),
            pct(&self.execute_time, 0.5),
            pct(&self.execute_time, 0.95),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let r = LatencyRecorder::new(100);
        for i in 1..=100 {
            r.record(Duration::from_millis(i));
        }
        let p50 = r.percentile_ms(0.5).unwrap();
        let p95 = r.percentile_ms(0.95).unwrap();
        assert!(p50 < p95);
        assert_eq!(r.count(), 100);
    }

    #[test]
    fn ring_caps_samples() {
        let r = LatencyRecorder::new(10);
        for i in 0..50 {
            r.record(Duration::from_millis(i));
        }
        assert_eq!(r.count(), 10);
        assert!(r.percentile_ms(0.0).unwrap() >= 40.0);
    }

    #[test]
    fn empty_recorder_is_none() {
        let r = LatencyRecorder::new(4);
        assert!(r.percentile_ms(0.5).is_none());
        assert!(r.mean_ms().is_none());
    }

    #[test]
    fn mean_batch_size() {
        let c = Counters::default();
        c.batches.store(2, Ordering::Relaxed);
        c.batched_requests.store(10, Ordering::Relaxed);
        assert_eq!(c.mean_batch_size(), 5.0);
        let report = Telemetry::default().report();
        assert!(report.contains("latency_ms"));
    }
}
