//! Per-request lifecycle tracing for the fleet dispatch spine, in
//! virtual time.
//!
//! A sampled request gets a [`TraceId`] at the front door and leaves a
//! trail of [`SpanRecord`]s as it moves through the system: gate
//! decision (`admit` / terminal `shed`), route decision (chosen
//! replica plus the losing candidates' scores), queue wait, batch
//! seal, cold artifact load, the scheduled execute window, and exactly
//! one terminal span (`completed` / `expired` / `lost` / `evicted` /
//! `shed`).  Spans land in a bounded ring and export as Chrome
//! trace-event JSON (`chrome://tracing` / Perfetto's legacy loader)
//! via [`Tracer::export_chrome`], surfaced by the server's
//! `{"cmd":"trace_dump"}` and the `--trace-out` flag on the `fleet`
//! subcommand and `trace_replay` example.
//!
//! Sampling defaults to **off**: the only cost on the dispatch path is
//! one relaxed atomic load per arrival ([`Tracer::sample`] returns
//! `None` immediately), which is what keeps the fleet benches
//! regression-free with observability compiled in.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::json::Json;

/// Identity of one sampled request, assigned at the admission gate.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TraceId(pub u64);

/// One lifecycle span in virtual time.  `track` groups spans per
/// replica in the exported view (0 = the gate/router track).
#[derive(Debug, Clone, PartialEq)]
pub struct SpanRecord {
    pub trace: TraceId,
    /// Span kind: `admit`, `route`, `queue`, `batch_seal`,
    /// `cold_load`, `execute`, or `terminal`.
    pub name: &'static str,
    /// Human detail (chosen replica, losing scores, outcome, ...).
    pub detail: String,
    pub start_ms: f64,
    pub dur_ms: f64,
    pub track: u32,
}

/// Default span-ring capacity (oldest spans drop first).
pub const DEFAULT_RING_CAP: usize = 16_384;

/// Sampling tracer with a bounded span ring.
#[derive(Debug)]
pub struct Tracer {
    /// Sample 1 in `every` arrivals; 0 = tracing off.
    every: AtomicU64,
    seen: AtomicU64,
    next_id: AtomicU64,
    cap: usize,
    ring: Mutex<VecDeque<SpanRecord>>,
}

impl Default for Tracer {
    fn default() -> Self {
        Self::new(DEFAULT_RING_CAP, 0)
    }
}

impl Tracer {
    pub fn new(cap: usize, every: u64) -> Tracer {
        Tracer {
            every: AtomicU64::new(every),
            seen: AtomicU64::new(0),
            next_id: AtomicU64::new(0),
            cap: cap.max(1),
            ring: Mutex::new(VecDeque::new()),
        }
    }

    /// A tracer with sampling disabled (the default posture).
    pub fn off() -> Tracer {
        Tracer::default()
    }

    /// Change the sampling rate (1 = every request, 0 = off).
    pub fn set_sampling(&self, every: u64) {
        self.every.store(every, Ordering::Relaxed);
    }

    /// One relaxed load — the entire cost when tracing is off.
    pub fn enabled(&self) -> bool {
        self.every.load(Ordering::Relaxed) != 0
    }

    /// Per-arrival sampling decision: `Some(id)` for 1 in `every`
    /// arrivals, `None` otherwise (and always when off).
    pub fn sample(&self) -> Option<TraceId> {
        let every = self.every.load(Ordering::Relaxed);
        if every == 0 {
            return None;
        }
        let n = self.seen.fetch_add(1, Ordering::Relaxed);
        if n % every != 0 {
            return None;
        }
        Some(TraceId(self.next_id.fetch_add(1, Ordering::Relaxed) + 1))
    }

    /// Record a span for a sampled request (caller already holds a
    /// `TraceId`, so this is never reached on the untraced path).
    pub fn record(&self, span: SpanRecord) {
        let mut ring = self.ring.lock().unwrap();
        if ring.len() == self.cap {
            ring.pop_front();
        }
        ring.push_back(span);
    }

    /// Convenience: build + record.
    #[allow(clippy::too_many_arguments)]
    pub fn event(
        &self,
        trace: TraceId,
        name: &'static str,
        detail: impl Into<String>,
        start_ms: f64,
        dur_ms: f64,
        track: u32,
    ) {
        self.record(SpanRecord {
            trace,
            name,
            detail: detail.into(),
            start_ms,
            dur_ms: dur_ms.max(0.0),
            track,
        });
    }

    pub fn len(&self) -> usize {
        self.ring.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn clear(&self) {
        self.ring.lock().unwrap().clear();
    }

    /// Snapshot of the span ring (oldest first).
    pub fn spans(&self) -> Vec<SpanRecord> {
        self.ring.lock().unwrap().iter().cloned().collect()
    }

    /// Export the ring as Chrome trace-event JSON: complete events
    /// (`ph:"X"`), timestamps in microseconds of virtual time, one
    /// `tid` per replica track.  Load the result in `chrome://tracing`
    /// or Perfetto.
    pub fn export_chrome(&self) -> Json {
        let events: Vec<Json> = self
            .ring
            .lock()
            .unwrap()
            .iter()
            .map(|s| {
                Json::object(vec![
                    ("name", Json::str(s.name)),
                    ("cat", Json::str("fleet")),
                    ("ph", Json::str("X")),
                    ("ts", Json::num(s.start_ms * 1e3)),
                    ("dur", Json::num(s.dur_ms * 1e3)),
                    ("pid", Json::num(1.0)),
                    ("tid", Json::num(s.track as f64)),
                    (
                        "args",
                        Json::object(vec![
                            ("trace", Json::num(s.trace.0 as f64)),
                            ("detail", Json::str(s.detail.clone())),
                        ]),
                    ),
                ])
            })
            .collect();
        Json::object(vec![
            ("displayTimeUnit", Json::str("ms")),
            ("traceEvents", Json::Array(events)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampling_off_is_the_default_and_free() {
        let t = Tracer::off();
        assert!(!t.enabled());
        for _ in 0..100 {
            assert!(t.sample().is_none());
        }
        assert!(t.is_empty());
    }

    #[test]
    fn sampling_rate_picks_one_in_k() {
        let t = Tracer::new(64, 4);
        assert!(t.enabled());
        let ids: Vec<_> = (0..20).filter_map(|_| t.sample()).collect();
        assert_eq!(ids.len(), 5, "1 in 4 of 20 arrivals");
        // IDs are unique and dense.
        for (i, id) in ids.iter().enumerate() {
            assert_eq!(id.0, i as u64 + 1);
        }
        t.set_sampling(0);
        assert!(t.sample().is_none());
    }

    #[test]
    fn ring_bounds_span_count() {
        let t = Tracer::new(4, 1);
        for i in 0..10 {
            t.event(TraceId(i), "terminal", "completed", i as f64, 0.0, 0);
        }
        let spans = t.spans();
        assert_eq!(spans.len(), 4);
        // Oldest dropped first.
        assert_eq!(spans[0].trace, TraceId(6));
        assert_eq!(spans[3].trace, TraceId(9));
    }

    #[test]
    fn chrome_export_shape() {
        let t = Tracer::new(16, 1);
        let id = t.sample().unwrap();
        t.event(id, "route", "r0/s7@fp32 (runner-up r1 score 1.2)", 10.0, 0.0, 0);
        t.event(id, "execute", "", 12.5, 55.8, 1);
        let out = t.export_chrome();
        let events = out.get("traceEvents").unwrap().as_array().unwrap();
        assert_eq!(events.len(), 2);
        let exec = &events[1];
        assert_eq!(exec.get("ph").unwrap().as_str(), Some("X"));
        // ms -> µs
        assert_eq!(exec.get("ts").unwrap().as_f64(), Some(12_500.0));
        assert_eq!(exec.get("dur").unwrap().as_f64(), Some(55_800.0));
        assert_eq!(exec.get("tid").unwrap().as_f64(), Some(1.0));
        assert_eq!(
            exec.get("args").unwrap().get("trace").unwrap().as_f64(),
            Some(id.0 as f64)
        );
        // The export round-trips through the parser.
        let text = out.to_string();
        assert!(Json::parse(&text).is_ok());
    }

    #[test]
    fn negative_durations_clamp_to_zero() {
        let t = Tracer::new(4, 1);
        t.event(TraceId(1), "queue", "", 5.0, -1.0, 0);
        assert_eq!(t.spans()[0].dur_ms, 0.0);
    }
}
