//! Application configuration (JSON file + CLI overrides).
//!
//! Example `mobile-convnet.json`:
//! ```json
//! {
//!   "artifacts_dir": "artifacts",
//!   "server_addr": "127.0.0.1:7878",
//!   "max_batch": 8,
//!   "max_wait_ms": 5.0,
//!   "batches": [1, 2, 4, 8],
//!   "precisions": ["precise", "imprecise"],
//!   "fleet": "2xs7,2x6p,2xn5",
//!   "fleet_policy": "energy",
//!   "fleet_budget_j": 50.0,
//!   "fleet_batch": 8,
//!   "fleet_batch_wait_ms": 25.0,
//!   "fleet_cache": 12.0,
//!   "fleet_autoscale": {
//!     "slo_p95_ms": 600.0,
//!     "warm_pool": "2xn5@fp16,1x6p@fp16",
//!     "min_replicas": 1,
//!     "max_replicas": 8,
//!     "fleet_budget_j": 300.0,
//!     "tick_ms": 500.0
//!   }
//! }
//! ```
//!
//! The fleet topology can also come from the environment
//! (`MCN_FLEET`, `MCN_FLEET_POLICY`, `MCN_FLEET_BUDGET_J`,
//! `MCN_FLEET_BATCH`, `MCN_FLEET_BATCH_WAIT_MS`, `MCN_FLEET_CACHE`,
//! `MCN_FLEET_SHARDS`) or the CLI
//! (`--fleet SPEC --fleet-policy P --fleet-budget-j J --fleet-batch B
//! --fleet-batch-wait-ms W --fleet-cache MB --fleet-shards M`); CLI
//! wins over env, env over file.
//! `fleet_shards` (default 1) partitions the fleet's replicas across
//! M coordinator shards behind the consistent-hash front door
//! ([`crate::coordinator::ShardedFleet`]); it requires a fleet when
//! M > 1.
//! `fleet_policy` accepts `energy:<λ>` (J/ms) to pin the energy-aware
//! latency price explicitly; a plain `energy` uses the fixed default,
//! which `fleet_autoscale` re-derives from `slo_p95_ms`
//! ([`Policy::lambda_for_slo`](crate::fleet::Policy::lambda_for_slo)).
//! `fleet_batch` > 1 turns on per-replica dynamic batching (requests
//! accumulate into amortized multi-image dispatches); the default of 1
//! keeps single-image service.  `fleet_cache` (megabytes per replica)
//! attaches the model-artifact tier: the fleet serves the default
//! two-model catalog (`squeezenet` ≈ 5 MB, `detector` ≈ 10 MB), each
//! replica keeps an LRU artifact cache of that capacity, cold loads
//! cost virtual time and joules, and placement becomes
//! affinity-aware (see [`crate::fleet::cache`]).
//!
//! `fleet_autoscale` attaches the closed-loop autoscaler (and turns on
//! idle-energy metering): a JSON object with the field names of
//! [`AutoscaleConfig`] (`warm_pool` as a fleet spec string), or the
//! compact `key=value` form [`AutoscaleConfig::parse`] accepts —
//! which is also what `MCN_FLEET_AUTOSCALE` and `--fleet-autoscale`
//! take, e.g. `"slo=600,pool=2xn5@fp16+1x6p@fp16,max=6,budget=300"`.
//! It requires a fleet to be configured.

use std::path::{Path, PathBuf};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::{BatcherConfig, CoordinatorConfig};
use crate::fleet::autoscaler::parse_pool;
use crate::fleet::{AutoscaleConfig, FleetConfig, Policy};
use crate::runtime::artifacts;
use crate::simulator::device::Precision;
use crate::util::json::Json;

/// Top-level application config.
#[derive(Debug, Clone)]
pub struct AppConfig {
    pub artifacts_dir: PathBuf,
    pub server_addr: String,
    pub max_batch: usize,
    pub max_wait: Duration,
    pub batches: Vec<usize>,
    pub precisions: Vec<Precision>,
    /// Simulated device fleet behind the server (None = single-path).
    pub fleet: Option<FleetConfig>,
    /// Coordinator shards for the fleet front door (1 = the classic
    /// single-fleet server; M > 1 partitions the replicas across M
    /// shards behind the consistent-hash router).
    pub fleet_shards: usize,
}

impl Default for AppConfig {
    fn default() -> Self {
        Self {
            artifacts_dir: artifacts::default_dir(),
            server_addr: "127.0.0.1:7878".into(),
            max_batch: 8,
            max_wait: Duration::from_millis(5),
            batches: vec![1, 2, 4, 8],
            precisions: vec![Precision::Precise, Precision::Imprecise],
            fleet: None,
            fleet_shards: 1,
        }
    }
}

/// Default flush deadline when per-replica batching is on but no wait
/// was configured: long enough to accumulate riders at serving rates,
/// short next to the 100–600 ms per-image service times.
pub const DEFAULT_FLEET_BATCH_WAIT_MS: f64 = 25.0;

/// Build a [`FleetConfig`] from a topology spec plus optional policy
/// name, per-replica budget, and batching knobs.  Default policy is
/// `energy` — the paper-derived router; default batching is off
/// (`max_batch` 1 = single-image service).
pub fn fleet_from(
    spec: &str,
    policy: Option<&str>,
    budget_j: Option<f64>,
    max_batch: Option<usize>,
    batch_wait_ms: Option<f64>,
    cache_mb: Option<f64>,
) -> Result<FleetConfig> {
    let policy = match policy {
        Some(p) => Policy::parse(p).map_err(|e| anyhow::anyhow!(e))?,
        None => Policy::EnergyAware { lambda_j_per_ms: None },
    };
    let mut cfg = FleetConfig::parse_spec(spec, policy)
        .map_err(|e| anyhow::anyhow!("fleet spec: {e}"))?;
    let max_batch = max_batch.unwrap_or(1);
    anyhow::ensure!((1..=64).contains(&max_batch), "fleet_batch must be 1..=64");
    let wait = batch_wait_ms.unwrap_or(DEFAULT_FLEET_BATCH_WAIT_MS);
    anyhow::ensure!(
        wait.is_finite() && wait >= 0.0,
        "fleet_batch_wait_ms must be a non-negative number"
    );
    if max_batch > 1 {
        cfg = cfg.with_batching(max_batch, wait);
    } else {
        // A wait with no batch cap would be silently meaningless;
        // reject it so the misconfiguration is visible.
        anyhow::ensure!(
            batch_wait_ms.is_none(),
            "fleet_batch_wait_ms requires fleet_batch > 1"
        );
    }
    if let Some(mb) = cache_mb {
        anyhow::ensure!(
            mb.is_finite() && mb > 0.0,
            "fleet_cache must be a positive number of megabytes per replica"
        );
        let capacity_bytes = (mb * 1e6) as u64;
        // A sub-microscopic capacity truncates to zero bytes; make it
        // a config error like every other bad knob, not a panic.
        anyhow::ensure!(
            capacity_bytes > 0,
            "fleet_cache of {mb} MB rounds to zero bytes per replica"
        );
        cfg = cfg.with_artifact_cache(capacity_bytes);
    }
    Ok(cfg.with_budget_j(budget_j))
}

/// Parse a `fleet_autoscale` config value: either the compact
/// `key=value` string [`AutoscaleConfig::parse`] accepts, or an object
/// with [`AutoscaleConfig`]'s field names (`warm_pool` as a fleet spec
/// string, commas allowed).
pub fn autoscale_from_json(v: &Json) -> Result<AutoscaleConfig> {
    if let Some(s) = v.as_str() {
        return AutoscaleConfig::parse(s).map_err(|e| anyhow::anyhow!(e));
    }
    // A typoed knob must be an error, not a silent default (the
    // compact-string parser already rejects unknown keys).
    const KNOWN: [&str; 13] = [
        "slo_p95_ms",
        "warm_pool",
        "min_replicas",
        "max_replicas",
        "fleet_budget_j",
        "tick_ms",
        "scale_up_after",
        "scale_down_after",
        "cooldown_ticks",
        "queue_per_replica",
        "calm_frac",
        "degrade_frac",
        "max_degrade_steps",
    ];
    if let Json::Object(pairs) = v {
        for (k, _) in pairs {
            anyhow::ensure!(
                KNOWN.contains(&k.as_str()),
                "fleet_autoscale: unknown key '{k}'"
            );
        }
    } else {
        anyhow::bail!("fleet_autoscale must be an object or a key=value string");
    }
    // Every knob errors on a wrong type too — `tick_ms: "250"` must
    // not silently keep the default.
    let count = |key: &str| -> Result<Option<usize>> {
        match v.get(key) {
            None => Ok(None),
            Some(x) => Ok(Some(x.as_usize().ok_or_else(|| {
                anyhow::anyhow!("fleet_autoscale: {key} must be a non-negative integer")
            })?)),
        }
    };
    let num = |key: &str| -> Result<Option<f64>> {
        match v.get(key) {
            None => Ok(None),
            Some(x) => Ok(Some(x.as_f64().ok_or_else(|| {
                anyhow::anyhow!("fleet_autoscale: {key} must be a number")
            })?)),
        }
    };
    let slo = num("slo_p95_ms")?
        .ok_or_else(|| anyhow::anyhow!("fleet_autoscale: slo_p95_ms is required"))?;
    let mut cfg = AutoscaleConfig::new(slo);
    if let Some(pool) = v.get("warm_pool") {
        let pool = pool
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("fleet_autoscale: warm_pool must be a spec string"))?;
        cfg.warm_pool = parse_pool(pool).map_err(|e| anyhow::anyhow!(e))?;
    }
    if let Some(n) = count("min_replicas")? {
        cfg.min_replicas = n;
    }
    if let Some(n) = count("max_replicas")? {
        cfg.max_replicas = n;
    }
    if let Some(b) = num("fleet_budget_j")? {
        cfg.fleet_budget_j = Some(b);
    }
    if let Some(t) = num("tick_ms")? {
        cfg.tick_ms = t;
    }
    if let Some(n) = count("scale_up_after")? {
        cfg.scale_up_after = n;
    }
    if let Some(n) = count("scale_down_after")? {
        cfg.scale_down_after = n;
    }
    if let Some(n) = count("cooldown_ticks")? {
        cfg.cooldown_ticks = n;
    }
    if let Some(n) = count("queue_per_replica")? {
        cfg.queue_per_replica = n;
    }
    if let Some(f) = num("calm_frac")? {
        cfg.calm_frac = f;
    }
    if let Some(f) = num("degrade_frac")? {
        cfg.degrade_frac = f;
    }
    if let Some(n) = count("max_degrade_steps")? {
        cfg.max_degrade_steps = n.min(u8::MAX as usize) as u8;
    }
    cfg.validate().map_err(|e| anyhow::anyhow!(e))?;
    Ok(cfg)
}

fn parse_precision(s: &str) -> Result<Precision> {
    match s {
        "precise" => Ok(Precision::Precise),
        "imprecise" => Ok(Precision::Imprecise),
        "int8" | "i8" => Ok(Precision::Int8),
        other => anyhow::bail!("unknown precision '{other}' (precise|imprecise|int8)"),
    }
}

impl AppConfig {
    /// Parse from JSON text; missing fields keep defaults.
    pub fn from_json(text: &str) -> Result<AppConfig> {
        let v = Json::parse(text).context("config: invalid JSON")?;
        let mut cfg = AppConfig::default();
        if let Some(d) = v.get("artifacts_dir").and_then(Json::as_str) {
            cfg.artifacts_dir = PathBuf::from(d);
        }
        if let Some(a) = v.get("server_addr").and_then(Json::as_str) {
            cfg.server_addr = a.to_string();
        }
        if let Some(n) = v.get("max_batch").and_then(Json::as_usize) {
            cfg.max_batch = n;
        }
        if let Some(ms) = v.get("max_wait_ms").and_then(Json::as_f64) {
            cfg.max_wait = Duration::from_secs_f64(ms / 1e3);
        }
        if let Some(b) = v.get("batches").and_then(Json::as_array) {
            cfg.batches = b.iter().filter_map(Json::as_usize).collect();
            anyhow::ensure!(cfg.batches.contains(&1), "config: batches must include 1");
        }
        if let Some(p) = v.get("precisions").and_then(Json::as_array) {
            cfg.precisions = p
                .iter()
                .filter_map(Json::as_str)
                .map(parse_precision)
                .collect::<Result<Vec<_>>>()?;
            anyhow::ensure!(!cfg.precisions.is_empty(), "config: precisions must be non-empty");
        }
        if let Some(spec) = v.get("fleet").and_then(Json::as_str) {
            let policy = v.get("fleet_policy").and_then(Json::as_str);
            let budget = v.get("fleet_budget_j").and_then(Json::as_f64);
            // Range validation (1..=64) lives in `fleet_from`; only the
            // integer-ness of the JSON value is checked here.
            let batch = match v.get("fleet_batch") {
                None => None,
                Some(b) => Some(
                    b.as_usize()
                        .ok_or_else(|| anyhow::anyhow!("config: fleet_batch must be an integer"))?,
                ),
            };
            let wait = v.get("fleet_batch_wait_ms").and_then(Json::as_f64);
            let cache_mb = match v.get("fleet_cache") {
                None => None,
                Some(c) => Some(c.as_f64().ok_or_else(|| {
                    anyhow::anyhow!("config: fleet_cache must be a number (MB per replica)")
                })?),
            };
            cfg.fleet = Some(
                fleet_from(spec, policy, budget, batch, wait, cache_mb)
                    .context("config: fleet")?,
            );
        }
        if let Some(a) = v.get("fleet_autoscale") {
            let autoscale = autoscale_from_json(a).context("config: fleet_autoscale")?;
            match cfg.fleet.take() {
                Some(f) => cfg.fleet = Some(f.with_autoscale(autoscale)),
                None => anyhow::bail!("config: fleet_autoscale requires a fleet"),
            }
        }
        if let Some(m) = v.get("fleet_shards") {
            let m = m
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("config: fleet_shards must be an integer"))?;
            anyhow::ensure!(m >= 1, "config: fleet_shards must be >= 1");
            anyhow::ensure!(
                m == 1 || cfg.fleet.is_some(),
                "config: fleet_shards > 1 requires a fleet"
            );
            cfg.fleet_shards = m;
        }
        Ok(cfg)
    }

    /// Apply `MCN_FLEET` / `MCN_FLEET_POLICY` / `MCN_FLEET_BUDGET_J` /
    /// `MCN_FLEET_BATCH` / `MCN_FLEET_BATCH_WAIT_MS` /
    /// `MCN_FLEET_CACHE` / `MCN_FLEET_AUTOSCALE` environment overrides
    /// (spec presence gates the batch/policy/cache knobs;
    /// `MCN_FLEET_AUTOSCALE` applies to whichever fleet is configured,
    /// env or file).
    pub fn apply_env(&mut self) -> Result<()> {
        if let Ok(spec) = std::env::var("MCN_FLEET") {
            let policy = std::env::var("MCN_FLEET_POLICY").ok();
            let budget = match std::env::var("MCN_FLEET_BUDGET_J") {
                Ok(v) => Some(
                    v.parse::<f64>()
                        .map_err(|_| anyhow::anyhow!("MCN_FLEET_BUDGET_J: bad number '{v}'"))?,
                ),
                Err(_) => None,
            };
            let batch = match std::env::var("MCN_FLEET_BATCH") {
                Ok(v) => Some(
                    v.parse::<usize>()
                        .map_err(|_| anyhow::anyhow!("MCN_FLEET_BATCH: bad count '{v}'"))?,
                ),
                Err(_) => None,
            };
            let wait = match std::env::var("MCN_FLEET_BATCH_WAIT_MS") {
                Ok(v) => Some(v.parse::<f64>().map_err(|_| {
                    anyhow::anyhow!("MCN_FLEET_BATCH_WAIT_MS: bad number '{v}'")
                })?),
                Err(_) => None,
            };
            let cache_mb = match std::env::var("MCN_FLEET_CACHE") {
                Ok(v) => Some(
                    v.parse::<f64>()
                        .map_err(|_| anyhow::anyhow!("MCN_FLEET_CACHE: bad number '{v}'"))?,
                ),
                Err(_) => None,
            };
            self.fleet = Some(
                fleet_from(&spec, policy.as_deref(), budget, batch, wait, cache_mb)
                    .context("MCN_FLEET")?,
            );
        }
        if let Ok(kv) = std::env::var("MCN_FLEET_AUTOSCALE") {
            let autoscale = AutoscaleConfig::parse(&kv)
                .map_err(|e| anyhow::anyhow!(e))
                .context("MCN_FLEET_AUTOSCALE")?;
            match self.fleet.take() {
                Some(f) => self.fleet = Some(f.with_autoscale(autoscale)),
                None => anyhow::bail!("MCN_FLEET_AUTOSCALE requires a fleet (MCN_FLEET or config)"),
            }
        }
        if let Ok(v) = std::env::var("MCN_FLEET_SHARDS") {
            let m = v
                .parse::<usize>()
                .map_err(|_| anyhow::anyhow!("MCN_FLEET_SHARDS: bad count '{v}'"))?;
            anyhow::ensure!(m >= 1, "MCN_FLEET_SHARDS must be >= 1");
            anyhow::ensure!(
                m == 1 || self.fleet.is_some(),
                "MCN_FLEET_SHARDS > 1 requires a fleet (MCN_FLEET or config)"
            );
            self.fleet_shards = m;
        }
        Ok(())
    }

    /// Load from a file.
    pub fn load(path: &Path) -> Result<AppConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::from_json(&text)
    }

    /// Convert into the coordinator's construction parameters.
    pub fn coordinator_config(&self) -> CoordinatorConfig {
        CoordinatorConfig {
            artifacts_dir: self.artifacts_dir.clone(),
            precisions: self.precisions.clone(),
            batches: self.batches.clone(),
            batcher: BatcherConfig { max_batch: self.max_batch, max_wait: self.max_wait },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = AppConfig::default();
        assert!(c.batches.contains(&1));
        assert_eq!(c.precisions.len(), 2);
    }

    #[test]
    fn parses_overrides() {
        let c = AppConfig::from_json(
            r#"{"server_addr": "0.0.0.0:9", "max_batch": 4, "max_wait_ms": 2.5,
                "batches": [1, 2], "precisions": ["imprecise"]}"#,
        )
        .unwrap();
        assert_eq!(c.server_addr, "0.0.0.0:9");
        assert_eq!(c.max_batch, 4);
        assert_eq!(c.max_wait, Duration::from_micros(2500));
        assert_eq!(c.batches, vec![1, 2]);
        assert_eq!(c.precisions, vec![Precision::Imprecise]);
        // the quantized tier and its short alias
        let c = AppConfig::from_json(r#"{"precisions": ["precise", "int8", "i8"]}"#).unwrap();
        assert_eq!(
            c.precisions,
            vec![Precision::Precise, Precision::Int8, Precision::Int8]
        );
    }

    #[test]
    fn rejects_bad_config() {
        assert!(AppConfig::from_json("nope").is_err());
        assert!(AppConfig::from_json(r#"{"batches": [2, 4]}"#).is_err());
        assert!(AppConfig::from_json(r#"{"precisions": ["half"]}"#).is_err());
    }

    #[test]
    fn converts_to_coordinator_config() {
        let c = AppConfig::default().coordinator_config();
        assert_eq!(c.batcher.max_batch, 8);
        assert!(c.batches.contains(&8));
    }

    #[test]
    fn parses_fleet_block() {
        let c = AppConfig::from_json(
            r#"{"fleet": "2xs7,1xn5@fp16", "fleet_policy": "p2c", "fleet_budget_j": 12.5}"#,
        )
        .unwrap();
        let fleet = c.fleet.unwrap();
        assert_eq!(fleet.replicas.len(), 3);
        assert_eq!(fleet.policy, Policy::PowerOfTwoChoices);
        assert_eq!(fleet.budget_j, Some(12.5));
        // default config has no fleet; bad specs are errors
        assert!(AppConfig::default().fleet.is_none());
        assert!(AppConfig::from_json(r#"{"fleet": "9xpixel"}"#).is_err());
        assert!(AppConfig::from_json(r#"{"fleet": "s7", "fleet_policy": "rand"}"#).is_err());
    }

    #[test]
    fn parses_native_fleet_atoms() {
        // The `native` atom (real host inference) rides every config
        // surface the simulated atoms do: config file, fleet_from, and
        // mixed specs; precision selects the charged power rail only.
        let c = AppConfig::from_json(r#"{"fleet": "native,2xs7"}"#).unwrap();
        let fleet = c.fleet.unwrap();
        assert_eq!(fleet.replicas.len(), 3);
        assert_eq!(fleet.replicas[0].kind, crate::fleet::ReplicaKind::Native);
        assert_eq!(fleet.replicas[0].device.id, "host");
        assert_eq!(fleet.replicas[1].kind, crate::fleet::ReplicaKind::Simulated);
        let f = fleet_from("2xnative@fp16", Some("rr"), None, None, None, None).unwrap();
        assert_eq!(f.replicas.len(), 2);
        assert_eq!(f.replicas[0].precision, Precision::Imprecise);
        let c = AppConfig::from_json(r#"{"fleet": "native@int8"}"#).unwrap();
        assert_eq!(c.fleet.unwrap().replicas[0].precision, Precision::Int8);
    }

    #[test]
    fn parses_fleet_shards() {
        assert_eq!(AppConfig::default().fleet_shards, 1);
        let c = AppConfig::from_json(r#"{"fleet": "4xs7", "fleet_shards": 4}"#).unwrap();
        assert_eq!(c.fleet_shards, 4);
        // a single shard never needs a fleet; more than one does
        assert_eq!(AppConfig::from_json(r#"{"fleet_shards": 1}"#).unwrap().fleet_shards, 1);
        assert!(AppConfig::from_json(r#"{"fleet_shards": 4}"#).is_err());
        assert!(AppConfig::from_json(r#"{"fleet": "4xs7", "fleet_shards": 0}"#).is_err());
        assert!(AppConfig::from_json(r#"{"fleet": "4xs7", "fleet_shards": "many"}"#).is_err());
    }

    #[test]
    fn fleet_from_defaults_to_energy_aware() {
        let f = fleet_from("s7,n5", None, None, None, None, None).unwrap();
        assert!(matches!(f.policy, Policy::EnergyAware { .. }));
        assert_eq!(f.budget_j, None);
        assert!(!f.batch.enabled(), "batching is off by default");
        assert!(f.qos_aware, "fleets honor QoS by default");
        let f = fleet_from("s7", Some("rr"), Some(3.0), None, None, None).unwrap();
        assert_eq!(f.policy, Policy::RoundRobin);
        assert_eq!(f.budget_j, Some(3.0));
    }

    #[test]
    fn fleet_policy_accepts_explicit_lambda() {
        let c = AppConfig::from_json(r#"{"fleet": "s7,n5", "fleet_policy": "energy:0.008"}"#)
            .unwrap();
        assert_eq!(
            c.fleet.unwrap().policy,
            Policy::EnergyAware { lambda_j_per_ms: Some(0.008) }
        );
        assert!(
            AppConfig::from_json(r#"{"fleet": "s7", "fleet_policy": "energy:nope"}"#).is_err()
        );
        // an explicit λ survives autoscale attachment; a default λ is
        // re-derived from the SLO
        let c = AppConfig::from_json(
            r#"{"fleet": "s7,n5", "fleet_policy": "energy:0.008",
                "fleet_autoscale": "slo=500"}"#,
        )
        .unwrap();
        assert_eq!(
            c.fleet.unwrap().policy,
            Policy::EnergyAware { lambda_j_per_ms: Some(0.008) }
        );
        let c = AppConfig::from_json(
            r#"{"fleet": "s7,n5", "fleet_policy": "energy", "fleet_autoscale": "slo=500"}"#,
        )
        .unwrap();
        assert_eq!(
            c.fleet.unwrap().policy,
            Policy::EnergyAware { lambda_j_per_ms: Some(Policy::lambda_for_slo(500.0)) }
        );
    }

    #[test]
    fn parses_fleet_autoscale_block() {
        // object form
        let c = AppConfig::from_json(
            r#"{"fleet": "1xn5@fp16", "fleet_autoscale": {
                "slo_p95_ms": 600.0, "warm_pool": "2xn5@fp16,1x6p@fp16",
                "min_replicas": 1, "max_replicas": 6, "fleet_budget_j": 300.0,
                "tick_ms": 250.0, "queue_per_replica": 4}}"#,
        )
        .unwrap();
        let f = c.fleet.unwrap();
        assert!(f.idle_power, "autoscale turns idle metering on");
        let a = f.autoscale.unwrap();
        assert_eq!(a.slo_p95_ms, 600.0);
        assert_eq!(a.warm_pool.len(), 3);
        assert_eq!(a.max_replicas, 6);
        assert_eq!(a.fleet_budget_j, Some(300.0));
        assert_eq!(a.tick_ms, 250.0);
        assert_eq!(a.queue_per_replica, 4);
        // compact string form
        let c = AppConfig::from_json(
            r#"{"fleet": "1xn5", "fleet_autoscale": "slo=500,pool=2xs7+1xn5@fp16,max=4"}"#,
        )
        .unwrap();
        let a = c.fleet.unwrap().autoscale.unwrap();
        assert_eq!(a.slo_p95_ms, 500.0);
        assert_eq!(a.warm_pool.len(), 3);
        assert_eq!(a.max_replicas, 4);
        // autoscale without a fleet is an error, as are bad knobs
        assert!(AppConfig::from_json(r#"{"fleet_autoscale": "slo=500"}"#).is_err());
        assert!(
            AppConfig::from_json(r#"{"fleet": "1xn5", "fleet_autoscale": {}}"#).is_err(),
            "slo_p95_ms is required"
        );
        assert!(AppConfig::from_json(
            r#"{"fleet": "1xn5", "fleet_autoscale": {"slo_p95_ms": 500.0, "min_replicas": 0}}"#
        )
        .is_err());
        assert!(AppConfig::from_json(
            r#"{"fleet": "1xn5", "fleet_autoscale": "slo=500,pool=3xwatch"}"#
        )
        .is_err());
        // a typoed knob is an error, not a silent default
        assert!(AppConfig::from_json(
            r#"{"fleet": "1xn5", "fleet_autoscale": {"slo_p95_ms": 500.0, "max_replica": 2}}"#
        )
        .is_err());
        // so is a wrongly-typed value
        assert!(AppConfig::from_json(
            r#"{"fleet": "1xn5", "fleet_autoscale": {"slo_p95_ms": 500.0, "tick_ms": "250"}}"#
        )
        .is_err());
        assert!(AppConfig::from_json(
            r#"{"fleet": "1xn5", "fleet_autoscale": {"slo_p95_ms": 500.0, "warm_pool": ["n5"]}}"#
        )
        .is_err());
        // the fraction knobs parse and validate
        let c = AppConfig::from_json(
            r#"{"fleet": "1xn5", "fleet_autoscale": {
                "slo_p95_ms": 500.0, "calm_frac": 0.4, "degrade_frac": 0.9}}"#,
        )
        .unwrap();
        let a = c.fleet.unwrap().autoscale.unwrap();
        assert_eq!(a.calm_frac, 0.4);
        assert_eq!(a.degrade_frac, 0.9);
        // the degrade-chain depth knob parses and validates
        let c = AppConfig::from_json(
            r#"{"fleet": "1xn5", "fleet_autoscale": {
                "slo_p95_ms": 500.0, "max_degrade_steps": 1}}"#,
        )
        .unwrap();
        assert_eq!(c.fleet.unwrap().autoscale.unwrap().max_degrade_steps, 1);
        assert!(AppConfig::from_json(
            r#"{"fleet": "1xn5", "fleet_autoscale": {"slo_p95_ms": 500.0, "max_degrade_steps": 0}}"#
        )
        .is_err());
        assert!(AppConfig::from_json(
            r#"{"fleet": "1xn5", "fleet_autoscale": {"slo_p95_ms": 500.0, "calm_frac": 1.5}}"#
        )
        .is_err());
    }

    #[test]
    fn parses_fleet_cache_knob() {
        let c = AppConfig::from_json(r#"{"fleet": "2xs7", "fleet_cache": 12.0}"#).unwrap();
        let f = c.fleet.unwrap();
        let cc = f.cache.expect("fleet_cache attaches the artifact tier");
        assert_eq!(cc.capacity_bytes, 12_000_000);
        assert_eq!(cc.catalog.len(), 2, "default two-model zoo");
        assert!(f.affinity_aware);
        // no knob, no tier
        let no_knob = AppConfig::from_json(r#"{"fleet": "2xs7"}"#).unwrap();
        assert!(no_knob.fleet.unwrap().cache.is_none());
        // bad knobs are errors
        assert!(AppConfig::from_json(r#"{"fleet": "s7", "fleet_cache": 0}"#).is_err());
        assert!(AppConfig::from_json(r#"{"fleet": "s7", "fleet_cache": -4.0}"#).is_err());
        assert!(AppConfig::from_json(r#"{"fleet": "s7", "fleet_cache": "big"}"#).is_err());
        assert!(fleet_from("s7", None, None, None, None, Some(f64::NAN)).is_err());
        // a capacity that truncates to zero bytes is an error, not a panic
        assert!(fleet_from("s7", None, None, None, None, Some(1e-7)).is_err());
    }

    #[test]
    fn parses_fleet_batching_knobs() {
        let c = AppConfig::from_json(
            r#"{"fleet": "2xs7", "fleet_batch": 8, "fleet_batch_wait_ms": 10.0}"#,
        )
        .unwrap();
        let f = c.fleet.unwrap();
        assert_eq!(f.batch.max_batch, 8);
        assert_eq!(f.batch.max_wait_ms, 10.0);
        assert_eq!(f.batch.sizes, vec![1, 2, 4, 8]);
        // wait defaults when only the cap is given
        let f = fleet_from("s7", None, None, Some(4), None, None).unwrap();
        assert_eq!(f.batch.max_wait_ms, DEFAULT_FLEET_BATCH_WAIT_MS);
        // bad knobs are errors
        assert!(AppConfig::from_json(r#"{"fleet": "s7", "fleet_batch": 0}"#).is_err());
        assert!(fleet_from("s7", None, None, Some(65), None, None).is_err());
        assert!(fleet_from("s7", None, None, Some(4), Some(-1.0), None).is_err());
        // a wait without a batch cap is a visible error, not a no-op
        assert!(fleet_from("s7", None, None, None, Some(10.0), None).is_err());
        assert!(
            AppConfig::from_json(r#"{"fleet": "s7", "fleet_batch_wait_ms": 10.0}"#).is_err()
        );
    }
}
